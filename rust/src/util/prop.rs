//! A miniature property-based testing harness (the vendored crate set has
//! no `proptest`/`quickcheck`).
//!
//! Provides the two pieces the test suites actually use:
//! * [`run_prop`] — run a property over `N` random cases from a seeded
//!   [`Pcg32`], reporting the failing seed/case for reproduction;
//! * [`shrink_u64`] — binary-search shrinking for scalar counterexamples.
//!
//! Properties take the per-case RNG so each case can draw arbitrarily
//! structured inputs; on failure we re-derive the exact case from
//! `(seed, index)` which is printed in the panic message.

use super::rng::Pcg32;

/// Run `cases` random cases of `prop`. Each case gets a fresh RNG derived
/// from `(seed, case_index)` so any failure is reproducible in isolation.
/// `prop` returns `Err(msg)` to fail the property.
///
/// Panics with the failing `(seed, case)` pair on first failure.
#[track_caller]
pub fn run_prop<F>(name: &str, seed: u64, cases: u64, mut prop: F)
where
    F: FnMut(&mut Pcg32) -> Result<(), String>,
{
    for case in 0..cases {
        let mut rng = Pcg32::new(seed, case + 1);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed at seed={seed} case={case}: {msg}\n\
                 reproduce with Pcg32::new({seed}, {})",
                case + 1
            );
        }
    }
}

/// Shrink a failing scalar input: find the smallest `x in [lo, hi]` for
/// which `fails(x)` is true, assuming monotonicity (if it is not
/// monotonic, the result is still a valid failing input, just maybe not
/// minimal). Used to produce readable counterexamples for size-dependent
/// invariants.
pub fn shrink_u64<F>(mut lo: u64, mut hi: u64, mut fails: F) -> u64
where
    F: FnMut(u64) -> bool,
{
    debug_assert!(fails(hi), "shrink_u64: hi must be a failing input");
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        if fails(mid) {
            hi = mid;
        } else {
            lo = mid + 1;
        }
    }
    hi
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        run_prop("always-true", 1, 50, |_rng| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'sometimes-false' failed")]
    fn failing_property_panics_with_seed() {
        run_prop("sometimes-false", 2, 100, |rng| {
            if rng.gen_bool(0.2) {
                Err("boom".into())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn failures_are_reproducible() {
        // Find a failing case, then re-derive it from (seed, case).
        let seed = 7;
        let mut failing_case = None;
        for case in 0..100u64 {
            let mut rng = Pcg32::new(seed, case + 1);
            if rng.gen_range(10) == 3 {
                failing_case = Some(case);
                break;
            }
        }
        let case = failing_case.expect("some case draws 3");
        let mut rng = Pcg32::new(seed, case + 1);
        assert_eq!(rng.gen_range(10), 3);
    }

    #[test]
    fn shrink_finds_boundary() {
        // Property fails for x >= 37.
        let min = shrink_u64(0, 1000, |x| x >= 37);
        assert_eq!(min, 37);
    }
}
