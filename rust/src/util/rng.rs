//! PCG32 pseudo-random number generator (O'Neill, PCG-XSH-RR 64/32).
//!
//! The vendored crate set has no `rand`, and determinism is a feature for
//! a simulator anyway: every workload tensor, every density draw, and
//! every tie-break in the schedulers is derived from an explicit seed so
//! a run is bit-reproducible across machines.

/// A 64-bit-state, 32-bit-output PCG generator.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Create a generator from a seed and a stream id. Different stream
    /// ids yield statistically independent sequences for the same seed.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 {
            state: 0,
            inc: (stream << 1) | 1,
        };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience constructor with stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Next raw 32-bit output.
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next 64-bit output (two 32-bit draws).
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform f64 in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) via Lemire's multiply-shift rejection.
    pub fn gen_range(&mut self, n: u32) -> u32 {
        debug_assert!(n > 0);
        let mut x = self.next_u32();
        let mut m = (x as u64).wrapping_mul(n as u64);
        let mut l = m as u32;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u32();
                m = (x as u64).wrapping_mul(n as u64);
                l = m as u32;
            }
        }
        (m >> 32) as u32
    }

    /// Bernoulli draw with probability `p`.
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Approximately normal draw (Irwin–Hall sum of 12 uniforms), mean 0,
    /// stddev 1. Plenty for density-jitter modelling.
    pub fn gen_normal(&mut self) -> f64 {
        let mut s = 0.0;
        for _ in 0..12 {
            s += self.next_f64();
        }
        s - 6.0
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        if xs.len() < 2 {
            return;
        }
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg32::new(42, 7);
        let mut b = Pcg32::new(42, 7);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg32::new(42, 1);
        let mut b = Pcg32::new(42, 2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4, "streams should be nearly disjoint, {same} collisions");
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg32::seeded(1);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut r = Pcg32::seeded(3);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let v = r.gen_range(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit in 1000 draws");
    }

    #[test]
    fn bernoulli_rate_close() {
        let mut r = Pcg32::seeded(9);
        let hits = (0..20_000).filter(|_| r.gen_bool(0.3)).count() as f64;
        let rate = hits / 20_000.0;
        assert!((rate - 0.3).abs() < 0.02, "rate {rate}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::seeded(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gen_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seeded(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>(), "shuffle changed order");
    }
}
