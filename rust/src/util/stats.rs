//! Streaming summary statistics used by the bench harness and the
//! simulator's per-component counters.

/// Online mean/min/max/stddev accumulator (Welford).
#[derive(Debug, Clone, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Population variance.
    pub fn var(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.var().sqrt()
    }

    /// Coefficient of variation (stddev/mean); 0 when mean is 0.
    pub fn cv(&self) -> f64 {
        let m = self.mean();
        if m == 0.0 {
            0.0
        } else {
            self.stddev() / m
        }
    }
}

/// Percentile over a sorted copy. `q` in [0,1].
///
/// Sorts with `f64::total_cmp`: `partial_cmp().unwrap()` here used to
/// abort the whole report when any sample was NaN (e.g. a 0/0 from a
/// zero-duration bench division). Under the IEEE total order a
/// (positive) NaN simply sorts after `+inf`, so low/mid percentiles
/// stay meaningful and only the quantiles that actually land on the
/// NaN tail report it.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let pos = q.clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let frac = pos - lo as f64;
        v[lo] * (1.0 - frac) + v[hi] * frac
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_moments() {
        let mut s = Summary::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.add(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.stddev() - 2.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn empty_summary_is_zeroed() {
        let s = Summary::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.min(), 0.0);
        assert_eq!(s.max(), 0.0);
        assert_eq!(s.stddev(), 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 4.0);
        assert!((percentile(&xs, 0.5) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_unsorted_input() {
        let xs = [9.0, 1.0, 5.0];
        assert_eq!(percentile(&xs, 0.5), 5.0);
    }

    /// Regression: a NaN sample must not panic the sort (it used to,
    /// via `partial_cmp().unwrap()`), and must sort after every finite
    /// value so the lower percentiles remain usable.
    #[test]
    fn percentile_tolerates_nan_samples() {
        let xs = [2.0, f64::NAN, 1.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 0.5), 2.0);
        assert!(percentile(&xs, 1.0).is_nan());
        let infs = [f64::INFINITY, f64::NAN, 0.5];
        assert_eq!(percentile(&infs, 0.5), f64::INFINITY);
        assert!(percentile(&infs, 1.0).is_nan());
    }

    #[test]
    fn cv_zero_mean() {
        let mut s = Summary::new();
        s.add(0.0);
        s.add(0.0);
        assert_eq!(s.cv(), 0.0);
    }
}
