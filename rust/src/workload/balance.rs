//! GB-S inter-filter load balancing, BARISTA's variant (paper §3.3.3).
//!
//! SparTen's software Greedy Balancing sorts whole filters by density and
//! co-locates densest-with-sparsest pairs on one PE. BARISTA keeps the
//! density *sort* but drops co-location (which serializes pairs and idles
//! nodes at scale); instead it alternates the filter-to-node assignment
//! between increasing and decreasing density order on consecutive input
//! maps, so a node that got a dense filter for map `t` gets a sparse one
//! for map `t+1` — only two fixed output-channel permutations, undone by
//! a 2-1 multiplexor in the conversion unit (vs GB-H's full permutation
//! network).

use crate::tensor::MaskMatrix;

/// Filters sorted by descending density (total nnz). Returns the
/// permutation: `order[rank] = original_filter_index`. Ties break by
/// index so the order is deterministic.
pub fn gb_s_order(filters: &MaskMatrix) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..filters.rows).collect();
    let nnz: Vec<u64> = (0..filters.rows).map(|r| filters.row_nnz(r)).collect();
    idx.sort_by(|&a, &b| nnz[b].cmp(&nnz[a]).then(a.cmp(&b)));
    idx
}

/// The filter each node position receives for input map `map_idx`, given
/// the density-sorted order. Even maps walk the order forward
/// (descending density), odd maps backward (ascending): consecutive maps
/// see mutually-reverse orderings (the paper's two fixed permutations).
///
/// `positions` is the number of node slots being filled (e.g. one FGR
/// round = `fgrs` filters). Returns `positions` filter indices starting
/// at `round * positions` into the sorted order, wrapping filters that
/// run out (callers bound `round` so this only happens on the ragged
/// tail).
pub fn alternating_assignment(
    order: &[usize],
    positions: usize,
    round: usize,
    map_idx: usize,
    alternate: bool,
) -> Vec<usize> {
    let base = round * positions;
    (0..positions)
        .map(|slot| {
            let rank = if alternate && map_idx % 2 == 1 {
                base + (positions - 1 - slot)
            } else {
                base + slot
            };
            order[rank % order.len()]
        })
        .collect()
}

/// Work spread metric: coefficient of variation of per-position total
/// work when assigning `order` across `positions` nodes. Used by tests
/// and the ablation bench to show GB-S + alternation lowers the spread.
pub fn assignment_cv(filters: &MaskMatrix, assignment: &[Vec<usize>]) -> f64 {
    // assignment[map_idx][slot] = filter index
    let positions = assignment.first().map(|a| a.len()).unwrap_or(0);
    if positions == 0 {
        return 0.0;
    }
    let mut per_slot = vec![0u64; positions];
    for round in assignment {
        for (slot, &f) in round.iter().enumerate() {
            per_slot[slot] += filters.row_nnz(f);
        }
    }
    let mut s = crate::util::stats::Summary::new();
    for w in &per_slot {
        s.add(*w as f64);
    }
    s.cv()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::run_prop;
    use crate::util::rng::Pcg32;

    fn filters(seed: u64, rows: usize) -> MaskMatrix {
        let mut rng = Pcg32::seeded(seed);
        MaskMatrix::random(&mut rng, rows, 1024, 0.4, 0.3)
    }

    #[test]
    fn order_is_descending_density() {
        let f = filters(1, 64);
        let order = gb_s_order(&f);
        for w in order.windows(2) {
            assert!(f.row_nnz(w[0]) >= f.row_nnz(w[1]));
        }
    }

    #[test]
    fn order_is_permutation() {
        let f = filters(2, 100);
        let mut order = gb_s_order(&f);
        order.sort_unstable();
        assert_eq!(order, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn alternation_reverses_consecutive_maps() {
        let f = filters(3, 64);
        let order = gb_s_order(&f);
        let even = alternating_assignment(&order, 64, 0, 0, true);
        let odd = alternating_assignment(&order, 64, 0, 1, true);
        let mut rev = even.clone();
        rev.reverse();
        assert_eq!(odd, rev);
    }

    #[test]
    fn no_alternation_is_stable() {
        let f = filters(4, 64);
        let order = gb_s_order(&f);
        let a = alternating_assignment(&order, 64, 0, 0, false);
        let b = alternating_assignment(&order, 64, 0, 5, false);
        assert_eq!(a, b);
    }

    #[test]
    fn alternation_reduces_systematic_imbalance() {
        let f = filters(5, 64);
        let order = gb_s_order(&f);
        // 16 consecutive maps, one round of 64 filters.
        let with: Vec<Vec<usize>> = (0..16)
            .map(|m| alternating_assignment(&order, 64, 0, m, true))
            .collect();
        let without: Vec<Vec<usize>> = (0..16)
            .map(|m| alternating_assignment(&order, 64, 0, m, false))
            .collect();
        let cv_with = assignment_cv(&f, &with);
        let cv_without = assignment_cv(&f, &without);
        assert!(
            cv_with < cv_without * 0.5,
            "alternation should halve the spread: {cv_with} vs {cv_without}"
        );
    }

    #[test]
    fn rounds_cover_all_filters() {
        let f = filters(6, 128);
        let order = gb_s_order(&f);
        let mut seen = vec![false; 128];
        for round in 0..2 {
            for &fi in &alternating_assignment(&order, 64, round, 0, true) {
                seen[fi] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn prop_assignment_is_valid_slice_of_order() {
        run_prop("assignment validity", 0x6B5, 100, |rng| {
            let rows = 8 + rng.gen_range(120) as usize;
            let positions = 1 + rng.gen_range(64) as usize;
            let f = filters(rng.next_u64(), rows);
            let order = gb_s_order(&f);
            let rounds = (rows + positions - 1) / positions;
            let round = rng.gen_range(rounds as u32) as usize;
            let m = rng.gen_range(32) as usize;
            let a = alternating_assignment(&order, positions, round, m, true);
            if a.len() != positions {
                return Err("wrong length".into());
            }
            if a.iter().any(|&fi| fi >= rows) {
                return Err("out of range filter".into());
            }
            Ok(())
        });
    }
}
