//! Synthesizes the chunked bitmask tensors the simulator consumes.
//!
//! For each layer we generate:
//! * `filters` — `n` chunked mask vectors at the layer's filter density
//!   with per-filter jitter (pruning leaves filters unevenly dense — the
//!   inter-filter imbalance GB-S addresses);
//! * `windows` — a *sample* of the im2col windows at the layer's map
//!   density with per-window jitter (feature-map sparsity is dynamic and
//!   bursty — the imbalance telescoping/coloring absorb). The sample is
//!   capped (`SimConfig::window_cap`) and results are scaled by
//!   `scale()`; window statistics are stationary so sampling preserves
//!   comparative timing (DESIGN.md §Substitutions-4).

use crate::config::SimConfig;
use crate::tensor::{LayerGeom, MaskMatrix};
use crate::util::rng::Pcg32;
use crate::workload::networks::{network, Benchmark, NetworkSpec};

/// Relative density spread across filters (pruned-filter variation).
pub const FILTER_JITTER: f64 = 0.15;
/// Relative density spread across windows (dynamic ReLU variation,
/// larger than filter spread — paper §3.2: maps stray more than filters).
pub const WINDOW_JITTER: f64 = 0.30;

/// One layer's simulated workload.
#[derive(Debug, Clone)]
pub struct LayerWork {
    pub index: usize,
    pub geom: LayerGeom,
    /// Chunked filter masks, `n × chunks`.
    pub filters: MaskMatrix,
    /// Chunked window masks, `sampled × chunks`.
    pub windows: MaskMatrix,
    /// Total windows in the full minibatch (before sampling).
    pub total_windows: usize,
    /// Filter density used for this layer.
    pub filter_density: f64,
    /// Input-map density used for this layer.
    pub map_density: f64,
}

impl LayerWork {
    /// Multiplier to scale sampled-window counts up to the full layer.
    pub fn scale(&self) -> f64 {
        self.total_windows as f64 / self.windows.rows.max(1) as f64
    }

    /// Dense MACs for the full layer (minibatch), the Dense baseline's
    /// work and the normalization everything is compared against.
    pub fn dense_macs(&self, batch: usize) -> u64 {
        self.geom.dense_macs(batch)
    }

    /// Total effectual (two-sided matched) MACs across the *sampled*
    /// windows — the lower bound on two-sided sparse compute.
    pub fn matched_macs_sampled(&self) -> u64 {
        let mut total = 0u64;
        for f in 0..self.filters.rows {
            for w in 0..self.windows.rows {
                total += self.filters.matched_row(f, &self.windows, w);
            }
        }
        total
    }

    /// One-sided effectual MACs (input-map zeros skipped, filter zeros
    /// not) across sampled windows.
    pub fn one_sided_macs_sampled(&self) -> u64 {
        let wnnz: u64 = (0..self.windows.rows)
            .map(|w| self.windows.row_nnz(w))
            .sum();
        wnnz * self.filters.rows as u64
    }
}

/// A full network's workload.
#[derive(Debug, Clone)]
pub struct NetworkWork {
    pub spec: NetworkSpec,
    pub layers: Vec<LayerWork>,
    pub batch: usize,
}

impl NetworkWork {
    /// Generate the workload for `benchmark` under `cfg` (deterministic
    /// in `cfg.seed`).
    pub fn generate(benchmark: Benchmark, cfg: &SimConfig) -> NetworkWork {
        let spec = network(benchmark);
        Self::from_spec(spec, cfg)
    }

    /// Generate from an explicit spec (used by the end-to-end driver to
    /// inject *measured* densities).
    pub fn from_spec(spec: NetworkSpec, cfg: &SimConfig) -> NetworkWork {
        let densities = spec.layer_densities();
        let mut layers = Vec::with_capacity(spec.layers.len());
        for (i, (geom, (fd, md))) in spec.layers.iter().zip(densities).enumerate() {
            layers.push(Self::layer(i, geom, fd, md, cfg));
        }
        NetworkWork {
            spec,
            layers,
            batch: cfg.batch,
        }
    }

    /// Generate a single layer's workload (also used directly by tests
    /// and microbenches).
    pub fn layer(
        index: usize,
        geom: &LayerGeom,
        filter_density: f64,
        map_density: f64,
        cfg: &SimConfig,
    ) -> LayerWork {
        // Independent streams per (seed, layer, role) so changing the
        // window cap does not perturb filter masks.
        let mut frng = Pcg32::new(cfg.seed ^ 0xF11F, (index as u64) * 2 + 1);
        let mut wrng = Pcg32::new(cfg.seed ^ 0x3A95, (index as u64) * 2 + 2);
        let total_windows = geom.windows(cfg.batch);
        let sampled = if cfg.window_cap == 0 {
            total_windows
        } else {
            total_windows.min(cfg.window_cap)
        };
        let filters = MaskMatrix::random(
            &mut frng,
            geom.n,
            geom.vec_len(),
            filter_density,
            FILTER_JITTER,
        );
        let windows = MaskMatrix::random(
            &mut wrng,
            sampled,
            geom.vec_len(),
            map_density,
            WINDOW_JITTER,
        );
        LayerWork {
            index,
            geom: *geom,
            filters,
            windows,
            total_windows,
            filter_density,
            map_density,
        }
    }

    /// Total dense MACs for the minibatch.
    pub fn dense_macs(&self) -> u64 {
        self.spec.dense_macs(self.batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ArchKind;

    fn small_cfg() -> SimConfig {
        let mut c = SimConfig::paper(ArchKind::Barista);
        c.window_cap = 64;
        c.batch = 2;
        c
    }

    #[test]
    fn generation_is_deterministic() {
        let cfg = small_cfg();
        let a = NetworkWork::generate(Benchmark::AlexNet, &cfg);
        let b = NetworkWork::generate(Benchmark::AlexNet, &cfg);
        for (x, y) in a.layers.iter().zip(&b.layers) {
            assert_eq!(x.filters.get(0, 0), y.filters.get(0, 0));
            assert_eq!(x.windows.get(0, 0), y.windows.get(0, 0));
            assert_eq!(x.matched_macs_sampled(), y.matched_macs_sampled());
        }
    }

    #[test]
    fn seed_changes_workload() {
        let cfg = small_cfg();
        let mut cfg2 = small_cfg();
        cfg2.seed ^= 1;
        let a = NetworkWork::generate(Benchmark::AlexNet, &cfg);
        let b = NetworkWork::generate(Benchmark::AlexNet, &cfg2);
        assert_ne!(
            a.layers[0].windows.get(0, 0),
            b.layers[0].windows.get(0, 0)
        );
    }

    #[test]
    fn window_cap_respected_and_scaled() {
        let cfg = small_cfg();
        let w = NetworkWork::generate(Benchmark::VggNet, &cfg);
        for l in &w.layers {
            assert!(l.windows.rows <= 64);
            let scale = l.scale();
            assert!(
                (scale - l.total_windows as f64 / l.windows.rows as f64).abs() < 1e-9
            );
            assert!(scale >= 1.0);
        }
    }

    #[test]
    fn densities_near_target() {
        let cfg = small_cfg();
        let w = NetworkWork::generate(Benchmark::ResNet18, &cfg);
        for l in &w.layers {
            // Skip tiny layers where sampling noise dominates.
            if l.filters.rows * l.filters.chunks < 100 {
                continue;
            }
            let fd = l.filters.density();
            // Matrix density is per *allocated* cell, so the tail chunk's
            // truncation scales the target by vec_len / (chunks*128).
            let trunc = l.geom.vec_len() as f64
                / (l.filters.chunks * crate::tensor::CHUNK_BITS) as f64;
            let want = l.filter_density * trunc;
            assert!(
                (fd - want).abs() < 0.08,
                "layer {}: filter density {fd} vs truncation-adjusted target {want}",
                l.index,
            );
        }
    }

    #[test]
    fn matched_leq_one_sided_leq_dense() {
        let cfg = small_cfg();
        let w = NetworkWork::generate(Benchmark::AlexNet, &cfg);
        for l in &w.layers {
            let matched = l.matched_macs_sampled();
            let onesided = l.one_sided_macs_sampled();
            let dense = l.windows.rows as u64 * l.geom.vec_len() as u64 * l.geom.n as u64;
            assert!(matched <= onesided, "layer {}", l.index);
            assert!(onesided <= dense, "layer {}", l.index);
            assert!(matched > 0, "layer {} produced no work", l.index);
        }
    }

    #[test]
    fn filters_independent_of_window_cap() {
        let cfg = small_cfg();
        let mut cfg2 = small_cfg();
        cfg2.window_cap = 32;
        let a = NetworkWork::generate(Benchmark::AlexNet, &cfg);
        let b = NetworkWork::generate(Benchmark::AlexNet, &cfg2);
        for (x, y) in a.layers.iter().zip(&b.layers) {
            for f in 0..x.filters.rows {
                for c in 0..x.filters.chunks {
                    assert_eq!(x.filters.get(f, c), y.filters.get(f, c));
                }
            }
        }
    }
}
