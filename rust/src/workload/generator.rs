//! Synthesizes the chunked bitmask tensors the simulator consumes.
//!
//! For each layer we generate:
//! * `filters` — `n` chunked mask vectors at the layer's filter density
//!   with per-filter jitter (pruning leaves filters unevenly dense — the
//!   inter-filter imbalance GB-S addresses);
//! * `windows` — a *sample* of the im2col windows at the layer's map
//!   density with per-window jitter (feature-map sparsity is dynamic and
//!   bursty — the imbalance telescoping/coloring absorb). The sample is
//!   capped (`SimConfig::window_cap`) and results are scaled by
//!   `scale()`; window statistics are stationary so sampling preserves
//!   comparative timing (DESIGN.md §Substitutions-4).
//!
//! *How* the non-zeros are distributed is delegated to the config's
//! [`SparsityModel`] (DESIGN.md §Workloads); the default model draws
//! bit-identically to the pre-scenario generator.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

use crate::arch::PassTable;
use crate::config::SimConfig;
use crate::tensor::{LayerGeom, MaskMatrix, SUBCHUNKS};
use crate::util::rng::Pcg32;
use crate::workload::networks::{network, Benchmark, NetworkSpec};
use crate::workload::sparsity::SparsityModel;

/// Largest pass table worth retaining per (layer, parts) — paper-sized
/// workloads sit at a few MB; only uncapped (`window_cap: 0`) runs
/// exceed this, and they keep the pre-§Perf direct path instead of
/// churning hundreds of MB of table per layer.
pub const PASS_TABLE_MAX_BYTES: usize = 64 << 20;

/// Relative density spread across filters (pruned-filter variation).
pub const FILTER_JITTER: f64 = 0.15;
/// Relative density spread across windows (dynamic ReLU variation,
/// larger than filter spread — paper §3.2: maps stray more than filters).
pub const WINDOW_JITTER: f64 = 0.30;

/// One layer's simulated workload.
#[derive(Debug, Clone)]
pub struct LayerWork {
    pub index: usize,
    pub geom: LayerGeom,
    /// Chunked filter masks, `n × chunks`.
    pub filters: MaskMatrix,
    /// Chunked window masks, `sampled × chunks`.
    pub windows: MaskMatrix,
    /// Total windows in the full minibatch (before sampling).
    pub total_windows: usize,
    /// Filter density used for this layer.
    pub filter_density: f64,
    /// Input-map density used for this layer.
    pub map_density: f64,
    /// Shared pass-table slots, keyed by PE partition count. Clones
    /// share the slots (the masks are immutable), so a memoized
    /// workload builds each table once for a whole sweep (§Perf).
    tables: Arc<TableSlots>,
}

/// Lazily built [`PassTable`]s for one layer. `None` remembers that a
/// geometry cannot be tabulated so the build is not retried.
#[derive(Debug, Default)]
struct TableSlots {
    by_parts: Mutex<HashMap<usize, Option<Arc<PassTable>>>>,
}

impl LayerWork {
    /// The shared pass-cost table for `parts` PEs per node, built on
    /// first use. One table per (layer, parts) serves every rotation,
    /// every BARISTA policy variant and the baselines' matched-MAC
    /// accounting — across all runs that share this workload. `None`
    /// when the geometry cannot or should not be tabulated — lane
    /// overflow, or a table beyond [`PASS_TABLE_MAX_BYTES`] (uncapped
    /// `window_cap: 0` runs) — in which case the caller falls back to
    /// direct mask arithmetic, which is bit-identical.
    pub fn pass_table(&self, parts: usize) -> Option<Arc<PassTable>> {
        let mut slots = self.tables.by_parts.lock().unwrap();
        if let Some(t) = slots.get(&parts) {
            return t.clone();
        }
        // Budget the tiled build's *peak* footprint — final table plus
        // the transient SoA plane scratch — not just the table itself.
        let bytes = PassTable::build_bytes(
            self.filters.rows,
            self.windows.rows,
            self.filters.chunks,
            parts,
        );
        let built = if bytes > PASS_TABLE_MAX_BYTES {
            None
        } else {
            PassTable::build(&self.filters, &self.windows, parts).map(Arc::new)
        };
        slots.insert(parts, built.clone());
        built
    }

    /// [`matched_macs_sampled`](Self::matched_macs_sampled) through the
    /// shared pass table — bit-identical, but amortized across every
    /// architecture that asks. The direct method stays as independent
    /// ground truth for tests.
    pub fn matched_macs_sampled_cached(&self) -> u64 {
        match self.pass_table(SUBCHUNKS) {
            Some(t) => t.total_matched(),
            None => self.matched_macs_sampled(),
        }
    }

    /// Multiplier to scale sampled-window counts up to the full layer.
    pub fn scale(&self) -> f64 {
        self.total_windows as f64 / self.windows.rows.max(1) as f64
    }

    /// Dense MACs for the full layer (minibatch), the Dense baseline's
    /// work and the normalization everything is compared against.
    pub fn dense_macs(&self, batch: usize) -> u64 {
        self.geom.dense_macs(batch)
    }

    /// Total effectual (two-sided matched) MACs across the *sampled*
    /// windows — the lower bound on two-sided sparse compute.
    pub fn matched_macs_sampled(&self) -> u64 {
        let mut total = 0u64;
        for f in 0..self.filters.rows {
            for w in 0..self.windows.rows {
                total += self.filters.matched_row(f, &self.windows, w);
            }
        }
        total
    }

    /// One-sided effectual MACs (input-map zeros skipped, filter zeros
    /// not) across sampled windows.
    pub fn one_sided_macs_sampled(&self) -> u64 {
        let wnnz: u64 = (0..self.windows.rows)
            .map(|w| self.windows.row_nnz(w))
            .sum();
        wnnz * self.filters.rows as u64
    }
}

/// A full network's workload.
#[derive(Debug, Clone)]
pub struct NetworkWork {
    pub spec: NetworkSpec,
    pub layers: Vec<LayerWork>,
    pub batch: usize,
}

impl NetworkWork {
    /// Generate the workload for `benchmark` under `cfg` (deterministic
    /// in `cfg.seed`).
    pub fn generate(benchmark: Benchmark, cfg: &SimConfig) -> NetworkWork {
        let spec = network(benchmark);
        Self::from_spec(spec, cfg)
    }

    /// Generate from an explicit spec (used by the end-to-end driver to
    /// inject *measured* densities).
    pub fn from_spec(spec: NetworkSpec, cfg: &SimConfig) -> NetworkWork {
        let densities = spec.layer_densities();
        let nlayers = spec.layers.len();
        let mut layers = Vec::with_capacity(nlayers);
        for (i, (geom, (fd, md))) in spec.layers.iter().zip(densities).enumerate() {
            // Layer-decay *replaces* the derived depth profile: the
            // geometric shape applies to the network averages, so the
            // network mean is preserved (multiplying the default
            // profile instead would compound two decaying sequences and
            // inflate it). Densities the user pinned per layer always
            // win — reshaping them would simulate a network the user
            // never defined. Every other model is the identity, keeping
            // the default path bit-identical.
            let (fd, md) = match cfg.sparsity {
                SparsityModel::LayerDecay { .. } if spec.per_layer.is_none() => {
                    cfg.sparsity.depth_profile(
                        spec.filter_density,
                        spec.map_density,
                        i,
                        nlayers,
                    )
                }
                _ => (fd, md),
            };
            layers.push(Self::layer(i, geom, fd, md, cfg));
        }
        NetworkWork {
            spec,
            layers,
            batch: cfg.batch,
        }
    }

    /// Generate a single layer's workload (also used directly by tests
    /// and microbenches).
    pub fn layer(
        index: usize,
        geom: &LayerGeom,
        filter_density: f64,
        map_density: f64,
        cfg: &SimConfig,
    ) -> LayerWork {
        // Independent streams per (seed, layer, role) so changing the
        // window cap does not perturb filter masks.
        let mut frng = Pcg32::new(cfg.seed ^ 0xF11F, (index as u64) * 2 + 1);
        let mut wrng = Pcg32::new(cfg.seed ^ 0x3A95, (index as u64) * 2 + 2);
        let total_windows = geom.windows(cfg.batch);
        let sampled = if cfg.window_cap == 0 {
            total_windows
        } else {
            total_windows.min(cfg.window_cap)
        };
        let filters =
            cfg.sparsity
                .filter_masks(&mut frng, geom.n, geom.vec_len(), filter_density);
        let windows =
            cfg.sparsity
                .window_masks(&mut wrng, sampled, geom.vec_len(), map_density);
        LayerWork {
            index,
            geom: *geom,
            filters,
            windows,
            total_windows,
            filter_density,
            map_density,
            tables: Arc::default(),
        }
    }

    /// Total dense MACs for the minibatch.
    pub fn dense_macs(&self) -> u64 {
        self.spec.dense_macs(self.batch)
    }

    /// Memoized [`generate`](Self::generate): identical `(benchmark,
    /// seed, window_cap, batch, sparsity)` requests share one generated
    /// workload — and hence one set of pass tables — across the whole
    /// process, so an 8-architecture sweep synthesizes masks once
    /// instead of 8 times (§Perf). Those five fields are the only
    /// `SimConfig` inputs generation reads, which the
    /// `memo_key_covers_generation` test pins down.
    pub fn shared(benchmark: Benchmark, cfg: &SimConfig) -> Arc<NetworkWork> {
        let key = WorkKey {
            benchmark,
            seed: cfg.seed,
            window_cap: cfg.window_cap,
            batch: cfg.batch,
            sparsity: cfg.sparsity,
        };
        let slot = {
            let memo = WORK_MEMO.get_or_init(|| {
                Mutex::new(WorkMemo {
                    slots: HashMap::new(),
                    stamp: 0,
                })
            });
            let mut m = memo.lock().unwrap();
            m.stamp += 1;
            let stamp = m.stamp;
            let arc = {
                let e = m
                    .slots
                    .entry(key)
                    .or_insert_with(|| (stamp, Arc::new(OnceLock::new())));
                e.0 = stamp;
                e.1.clone()
            };
            if m.slots.len() > WORK_MEMO_CAP {
                // Evict the least-recently-used other entry; holders of
                // its Arc keep it alive, we just stop memoizing it.
                let victim = m
                    .slots
                    .iter()
                    .filter(|&(k, _)| *k != key)
                    .min_by_key(|&(_, v)| v.0)
                    .map(|(k, _)| *k);
                if let Some(v) = victim {
                    m.slots.remove(&v);
                }
            }
            arc
        };
        // Generation happens outside the memo lock: only callers of the
        // *same* key wait on it (that wait is exactly the dedup win).
        slot.get_or_init(|| Arc::new(NetworkWork::generate(benchmark, cfg)))
            .clone()
    }
}

/// The `SimConfig` fields workload generation depends on — the memo key.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct WorkKey {
    benchmark: Benchmark,
    seed: u64,
    window_cap: usize,
    batch: usize,
    sparsity: SparsityModel,
}

/// At most this many distinct workloads stay memoized (LRU beyond it).
/// A full report sweep uses one per benchmark.
const WORK_MEMO_CAP: usize = 8;

struct WorkMemo {
    slots: HashMap<WorkKey, (u64, Arc<OnceLock<Arc<NetworkWork>>>)>,
    stamp: u64,
}

static WORK_MEMO: OnceLock<Mutex<WorkMemo>> = OnceLock::new();

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ArchKind;

    fn small_cfg() -> SimConfig {
        let mut c = SimConfig::paper(ArchKind::Barista);
        c.window_cap = 64;
        c.batch = 2;
        c
    }

    #[test]
    fn generation_is_deterministic() {
        let cfg = small_cfg();
        let a = NetworkWork::generate(Benchmark::AlexNet, &cfg);
        let b = NetworkWork::generate(Benchmark::AlexNet, &cfg);
        for (x, y) in a.layers.iter().zip(&b.layers) {
            assert_eq!(x.filters.get(0, 0), y.filters.get(0, 0));
            assert_eq!(x.windows.get(0, 0), y.windows.get(0, 0));
            assert_eq!(x.matched_macs_sampled(), y.matched_macs_sampled());
        }
    }

    #[test]
    fn seed_changes_workload() {
        let cfg = small_cfg();
        let mut cfg2 = small_cfg();
        cfg2.seed ^= 1;
        let a = NetworkWork::generate(Benchmark::AlexNet, &cfg);
        let b = NetworkWork::generate(Benchmark::AlexNet, &cfg2);
        assert_ne!(
            a.layers[0].windows.get(0, 0),
            b.layers[0].windows.get(0, 0)
        );
    }

    #[test]
    fn window_cap_respected_and_scaled() {
        let cfg = small_cfg();
        let w = NetworkWork::generate(Benchmark::VggNet, &cfg);
        for l in &w.layers {
            assert!(l.windows.rows <= 64);
            let scale = l.scale();
            assert!(
                (scale - l.total_windows as f64 / l.windows.rows as f64).abs() < 1e-9
            );
            assert!(scale >= 1.0);
        }
    }

    #[test]
    fn densities_near_target() {
        let cfg = small_cfg();
        let w = NetworkWork::generate(Benchmark::ResNet18, &cfg);
        for l in &w.layers {
            // Skip tiny layers where sampling noise dominates.
            if l.filters.rows * l.filters.chunks < 100 {
                continue;
            }
            let fd = l.filters.density();
            // Matrix density is per *allocated* cell, so the tail chunk's
            // truncation scales the target by vec_len / (chunks*128).
            let trunc = l.geom.vec_len() as f64
                / (l.filters.chunks * crate::tensor::CHUNK_BITS) as f64;
            let want = l.filter_density * trunc;
            assert!(
                (fd - want).abs() < 0.08,
                "layer {}: filter density {fd} vs truncation-adjusted target {want}",
                l.index,
            );
        }
    }

    #[test]
    fn matched_leq_one_sided_leq_dense() {
        let cfg = small_cfg();
        let w = NetworkWork::generate(Benchmark::AlexNet, &cfg);
        for l in &w.layers {
            let matched = l.matched_macs_sampled();
            let onesided = l.one_sided_macs_sampled();
            let dense = l.windows.rows as u64 * l.geom.vec_len() as u64 * l.geom.n as u64;
            assert!(matched <= onesided, "layer {}", l.index);
            assert!(onesided <= dense, "layer {}", l.index);
            assert!(matched > 0, "layer {} produced no work", l.index);
        }
    }

    #[test]
    fn shared_memoizes_and_matches_generate() {
        let cfg = small_cfg();
        let a = NetworkWork::shared(Benchmark::AlexNet, &cfg);
        let b = NetworkWork::shared(Benchmark::AlexNet, &cfg);
        assert!(Arc::ptr_eq(&a, &b), "identical keys share one workload");
        let fresh = NetworkWork::generate(Benchmark::AlexNet, &cfg);
        for (x, y) in a.layers.iter().zip(&fresh.layers) {
            assert_eq!(x.filters.get(0, 0), y.filters.get(0, 0));
            assert_eq!(x.windows.get(0, 0), y.windows.get(0, 0));
            assert_eq!(x.matched_macs_sampled(), y.matched_macs_sampled());
        }
    }

    /// The memo key (benchmark, seed, window_cap, batch) must cover
    /// every config input generation reads: configs differing in any
    /// *other* field generate identical workloads.
    #[test]
    fn memo_key_covers_generation() {
        let mut a = SimConfig::paper(ArchKind::Barista);
        a.window_cap = 48;
        a.batch = 2;
        let mut b = SimConfig::paper(ArchKind::Dense); // different arch et al.
        b.window_cap = 48;
        b.batch = 2;
        b.seed = a.seed;
        let wa = NetworkWork::generate(Benchmark::ResNet18, &a);
        let wb = NetworkWork::generate(Benchmark::ResNet18, &b);
        for (x, y) in wa.layers.iter().zip(&wb.layers) {
            for f in 0..x.filters.rows {
                for c in 0..x.filters.chunks {
                    assert_eq!(x.filters.get(f, c), y.filters.get(f, c));
                }
            }
            for w in 0..x.windows.rows {
                for c in 0..x.windows.chunks {
                    assert_eq!(x.windows.get(w, c), y.windows.get(w, c));
                }
            }
        }
    }

    #[test]
    fn pass_table_cached_and_exact() {
        let cfg = small_cfg();
        let net = NetworkWork::generate(Benchmark::AlexNet, &cfg);
        let l = &net.layers[1];
        let t1 = l.pass_table(4).expect("paper geometry tabulates");
        let t2 = l.pass_table(4).unwrap();
        assert!(Arc::ptr_eq(&t1, &t2), "table built once per (layer, parts)");
        assert_eq!(t1.total_matched(), l.matched_macs_sampled());
        assert_eq!(l.matched_macs_sampled_cached(), l.matched_macs_sampled());
        // Clones share the slots.
        let clone = l.clone();
        let t3 = clone.pass_table(4).unwrap();
        assert!(Arc::ptr_eq(&t1, &t3));
    }

    /// Scenarios are part of the memo key: differing sparsity models
    /// never share a workload, and every non-default model actually
    /// changes the masks.
    #[test]
    fn sparsity_model_changes_workload_and_memo_key() {
        let base = small_cfg();
        let a = NetworkWork::shared(Benchmark::AlexNet, &base);
        for model in SparsityModel::ALL {
            if model == SparsityModel::Bernoulli {
                continue;
            }
            let mut cfg = small_cfg();
            cfg.sparsity = model;
            let b = NetworkWork::shared(Benchmark::AlexNet, &cfg);
            assert!(
                !Arc::ptr_eq(&a, &b),
                "{model}: scenario must not share the default workload"
            );
            let differs = a.layers.iter().zip(&b.layers).any(|(x, y)| {
                x.matched_macs_sampled() != y.matched_macs_sampled()
                    || x.filter_density != y.filter_density
            });
            assert!(differs, "{model}: scenario left the workload unchanged");
        }
    }

    /// The default scenario draws exactly the seed generator's masks —
    /// the bit-identical guarantee the PR-2 goldens rely on.
    #[test]
    fn default_scenario_is_bit_identical_to_direct_draws() {
        let cfg = small_cfg();
        assert_eq!(cfg.sparsity, SparsityModel::Bernoulli);
        let w = NetworkWork::generate(Benchmark::AlexNet, &cfg);
        for (i, l) in w.layers.iter().enumerate() {
            let mut frng = Pcg32::new(cfg.seed ^ 0xF11F, (i as u64) * 2 + 1);
            let mut wrng = Pcg32::new(cfg.seed ^ 0x3A95, (i as u64) * 2 + 2);
            let filters = MaskMatrix::random(
                &mut frng,
                l.geom.n,
                l.geom.vec_len(),
                l.filter_density,
                FILTER_JITTER,
            );
            let windows = MaskMatrix::random(
                &mut wrng,
                l.windows.rows,
                l.geom.vec_len(),
                l.map_density,
                WINDOW_JITTER,
            );
            for r in 0..filters.rows {
                for c in 0..filters.chunks {
                    assert_eq!(l.filters.get(r, c), filters.get(r, c), "layer {i}");
                }
            }
            for r in 0..windows.rows {
                for c in 0..windows.chunks {
                    assert_eq!(l.windows.get(r, c), windows.get(r, c), "layer {i}");
                }
            }
        }
    }

    /// The table budget accounts the tiled build's peak footprint —
    /// table plus both transient SoA plane sets — and paper-sized
    /// layers stay comfortably tabulated under it.
    #[test]
    fn pass_table_budget_counts_build_scratch() {
        let cfg = small_cfg();
        let net = NetworkWork::generate(Benchmark::AlexNet, &cfg);
        let l = &net.layers[1];
        let want = PassTable::build_bytes(l.filters.rows, l.windows.rows, l.filters.chunks, 4);
        let table_only = l.filters.rows * l.windows.rows * 4 * 2;
        assert!(want > table_only, "plane scratch must be accounted");
        assert!(want <= PASS_TABLE_MAX_BYTES, "paper layers stay tabulated");
        assert!(l.pass_table(4).is_some());
    }

    #[test]
    fn filters_independent_of_window_cap() {
        let cfg = small_cfg();
        let mut cfg2 = small_cfg();
        cfg2.window_cap = 32;
        let a = NetworkWork::generate(Benchmark::AlexNet, &cfg);
        let b = NetworkWork::generate(Benchmark::AlexNet, &cfg2);
        for (x, y) in a.layers.iter().zip(&b.layers) {
            for f in 0..x.filters.rows {
                for c in 0..x.filters.chunks {
                    assert_eq!(x.filters.get(f, c), y.filters.get(f, c));
                }
            }
        }
    }
}
