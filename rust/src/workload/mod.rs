//! CNN benchmark workloads (paper Table 1).
//!
//! [`networks`] holds the conv-layer tables of the five benchmarks with
//! the paper's measured network-average filter / input-map densities.
//! [`generator`] synthesizes the chunked bitmask tensors the simulator
//! consumes (see DESIGN.md §Substitutions for why masks at matched
//! densities preserve the paper's behaviour). [`balance`] implements the
//! GB-S inter-filter load-balancing variant (§3.3.3).

pub mod balance;
pub mod generator;
pub mod networks;

pub use balance::{alternating_assignment, gb_s_order};
pub use generator::{LayerWork, NetworkWork};
pub use networks::{network, Benchmark, NetworkSpec};
