//! CNN benchmark workloads (paper Table 1) and the scenario engine.
//!
//! [`networks`] holds the conv-layer tables of the five benchmarks with
//! the paper's measured network-average filter / input-map densities,
//! plus the registry for user-defined networks loaded from JSON.
//! [`generator`] synthesizes the chunked bitmask tensors the simulator
//! consumes (see DESIGN.md §Substitutions for why masks at matched
//! densities preserve the paper's behaviour); [`sparsity`] decides how
//! the non-zeros are *distributed* (DESIGN.md §Workloads). [`balance`]
//! implements the GB-S inter-filter load-balancing variant (§3.3.3).
//! [`traces`] ingests *measured* sparsity: versioned JSON traces fitted
//! to the closest [`SparsityModel`] parameters per layer and registered
//! as ordinary custom networks (DESIGN.md §Traces).

pub mod balance;
pub mod generator;
pub mod networks;
pub mod sparsity;
pub mod traces;

pub use balance::{alternating_assignment, gb_s_order};
pub use generator::{LayerWork, NetworkWork};
pub use networks::{
    load_network_file, network, register_custom_network, Benchmark, NetworkSpec,
};
pub use sparsity::SparsityModel;
pub use traces::{load_trace_file, load_trace_json, synthesize_trace_json, LoadedTrace, TraceFit};
