//! Conv-layer tables for the paper's five benchmarks (Table 1), plus
//! user-defined custom networks loaded from JSON.
//!
//! Built-in layer geometries are the standard published architectures;
//! densities are the paper's network averages (filter density from
//! magnitude pruning + retraining [23], input-map density from ReLU
//! statistics), with deterministic per-layer modulation: early layers
//! are denser, deep layers sparser — the universally observed profile
//! (e.g. SparTen Fig. 12, Cnvlutin Table 1) — normalized so the
//! *network average* matches Table 1 exactly.
//!
//! Custom networks ([`register_custom_network`], [`load_network_file`])
//! live in a process-wide registry and are addressed by a
//! [`Benchmark::Custom`] handle, so the whole stack — generator memo,
//! coordinator, service cache — treats them exactly like built-ins.
//! The service cache key folds the spec's content hash in (see
//! [`Benchmark::cache_token`]) so two customs sharing a name can never
//! alias a cached result.

use std::sync::{OnceLock, RwLock};

use crate::tensor::LayerGeom;
use crate::util::Json;

/// The five benchmarks of Table 1, plus registered custom networks.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Benchmark {
    AlexNet,
    ResNet18,
    InceptionV4,
    VggNet,
    ResNet50,
    /// A user-defined network: index into the process-wide registry
    /// (see [`register_custom_network`]).
    Custom(u16),
}

impl Benchmark {
    /// Ordered by increasing sparsity opportunity, as Figure 7's X axis.
    pub const ALL: [Benchmark; 5] = [
        Benchmark::AlexNet,
        Benchmark::ResNet18,
        Benchmark::InceptionV4,
        Benchmark::VggNet,
        Benchmark::ResNet50,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Benchmark::AlexNet => "alexnet",
            Benchmark::ResNet18 => "resnet18",
            Benchmark::InceptionV4 => "inception-v4",
            Benchmark::VggNet => "vggnet",
            Benchmark::ResNet50 => "resnet50",
            Benchmark::Custom(i) => custom_name(*i),
        }
    }

    /// Resolve a name: the built-ins first, then any custom network
    /// registered in this process.
    pub fn parse(s: &str) -> Option<Benchmark> {
        if let Some(b) = Self::ALL.iter().copied().find(|b| b.name() == s) {
            return Some(b);
        }
        let reg = registry().read().unwrap();
        reg.iter()
            .position(|c| c.name == s)
            .map(|i| Benchmark::Custom(i as u16))
    }

    /// The string the service cache key hashes for this network. For
    /// built-ins it is the plain name (keys are unchanged from earlier
    /// releases); for customs it folds in the spec's content hash, so
    /// two different specs can never alias — even across processes that
    /// registered different networks under the same name.
    pub fn cache_token(&self) -> String {
        match self {
            Benchmark::Custom(i) => {
                let reg = registry().read().unwrap();
                let c = &reg[*i as usize];
                format!("custom:{}:{:016x}", c.name, c.spec_hash)
            }
            _ => self.name().to_string(),
        }
    }
}

impl std::fmt::Display for Benchmark {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// A benchmark's full conv-layer specification.
#[derive(Debug, Clone)]
pub struct NetworkSpec {
    pub benchmark: Benchmark,
    pub layers: Vec<LayerGeom>,
    /// Network-average filter density (Table 1).
    pub filter_density: f64,
    /// Network-average input-map density (Table 1).
    pub map_density: f64,
    /// Explicit per-layer `(filter, map)` densities. `None` derives the
    /// standard depth profile from the network averages; custom
    /// networks may pin every layer instead.
    pub per_layer: Option<Vec<(f64, f64)>>,
}

impl NetworkSpec {
    /// Per-layer (filter, map) densities: the spec's explicit table if
    /// it has one, otherwise a deterministic depth profile normalized
    /// so averages match Table 1. Input maps of layer 0 are raw images
    /// (density ≈ 1.0 conceptually, but the paper reports the network
    /// average including layer 0 — we use the same profile for
    /// simplicity and normalize across all layers).
    pub fn layer_densities(&self) -> Vec<(f64, f64)> {
        if let Some(pl) = &self.per_layer {
            return pl.clone();
        }
        profile(self.layers.len(), self.filter_density, self.map_density)
    }

    /// Total dense MACs for a minibatch.
    pub fn dense_macs(&self, batch: usize) -> u64 {
        self.layers.iter().map(|g| g.dense_macs(batch)).sum()
    }
}

/// Depth-decaying density profile with average pinned to `avg`:
/// raw_i = clamp(avg * (1.25 - 0.5 * i/(L-1)), lo, hi), then rescaled.
fn profile(layers: usize, filter_avg: f64, map_avg: f64) -> Vec<(f64, f64)> {
    let shape = |i: usize, avg: f64| -> f64 {
        let t = if layers <= 1 {
            0.5
        } else {
            i as f64 / (layers - 1) as f64
        };
        (avg * (1.25 - 0.5 * t)).clamp(0.02, 0.98)
    };
    let mut fs: Vec<f64> = (0..layers).map(|i| shape(i, filter_avg)).collect();
    let mut ms: Vec<f64> = (0..layers).map(|i| shape(i, map_avg)).collect();
    // Pin the mean exactly (scaling preserves the monotone profile).
    let rescale = |v: &mut Vec<f64>, avg: f64| {
        let mean = v.iter().sum::<f64>() / v.len() as f64;
        let s = avg / mean;
        for x in v.iter_mut() {
            *x = (*x * s).clamp(0.02, 0.98);
        }
    };
    rescale(&mut fs, filter_avg);
    rescale(&mut ms, map_avg);
    fs.into_iter().zip(ms).collect()
}

fn conv(h: usize, w: usize, d: usize, k: usize, n: usize, stride: usize, pad: usize) -> LayerGeom {
    LayerGeom {
        h,
        w,
        d,
        k,
        n,
        stride,
        pad,
    }
}

/// Build the layer table for a benchmark.
pub fn network(b: Benchmark) -> NetworkSpec {
    match b {
        Benchmark::AlexNet => NetworkSpec {
            benchmark: b,
            // The classic 5 conv layers (224×224 ImageNet input).
            layers: vec![
                conv(224, 224, 3, 11, 96, 4, 2),
                conv(27, 27, 96, 5, 256, 1, 2),
                conv(13, 13, 256, 3, 384, 1, 1),
                conv(13, 13, 384, 3, 384, 1, 1),
                conv(13, 13, 384, 3, 256, 1, 1),
            ],
            filter_density: 0.368,
            map_density: 0.473,
            per_layer: None,
        },
        Benchmark::VggNet => NetworkSpec {
            benchmark: b,
            // VGG-16's 13 conv layers.
            layers: vec![
                conv(224, 224, 3, 3, 64, 1, 1),
                conv(224, 224, 64, 3, 64, 1, 1),
                conv(112, 112, 64, 3, 128, 1, 1),
                conv(112, 112, 128, 3, 128, 1, 1),
                conv(56, 56, 128, 3, 256, 1, 1),
                conv(56, 56, 256, 3, 256, 1, 1),
                conv(56, 56, 256, 3, 256, 1, 1),
                conv(28, 28, 256, 3, 512, 1, 1),
                conv(28, 28, 512, 3, 512, 1, 1),
                conv(28, 28, 512, 3, 512, 1, 1),
                conv(14, 14, 512, 3, 512, 1, 1),
                conv(14, 14, 512, 3, 512, 1, 1),
                conv(14, 14, 512, 3, 512, 1, 1),
            ],
            filter_density: 0.334,
            map_density: 0.446,
            per_layer: None,
        },
        Benchmark::ResNet18 => NetworkSpec {
            benchmark: b,
            // conv1 + 8 basic blocks × 2 convs = 17 layers (Table 1).
            layers: {
                let mut v = vec![conv(224, 224, 3, 7, 64, 2, 3)];
                // stage 1: 56×56, 64ch
                for _ in 0..2 {
                    v.push(conv(56, 56, 64, 3, 64, 1, 1));
                    v.push(conv(56, 56, 64, 3, 64, 1, 1));
                }
                // stage 2: first block downsamples 56→28, 64→128
                v.push(conv(56, 56, 64, 3, 128, 2, 1));
                v.push(conv(28, 28, 128, 3, 128, 1, 1));
                v.push(conv(28, 28, 128, 3, 128, 1, 1));
                v.push(conv(28, 28, 128, 3, 128, 1, 1));
                // stage 3: 28→14, 128→256
                v.push(conv(28, 28, 128, 3, 256, 2, 1));
                v.push(conv(14, 14, 256, 3, 256, 1, 1));
                v.push(conv(14, 14, 256, 3, 256, 1, 1));
                v.push(conv(14, 14, 256, 3, 256, 1, 1));
                // stage 4: 14→7, 256→512
                v.push(conv(14, 14, 256, 3, 512, 2, 1));
                v.push(conv(7, 7, 512, 3, 512, 1, 1));
                v.push(conv(7, 7, 512, 3, 512, 1, 1));
                v.push(conv(7, 7, 512, 3, 512, 1, 1));
                v
            },
            filter_density: 0.336,
            map_density: 0.486,
            per_layer: None,
        },
        Benchmark::ResNet50 => NetworkSpec {
            benchmark: b,
            // conv1 + 16 bottleneck blocks × 3 convs = 49 layers.
            layers: {
                let mut v = vec![conv(224, 224, 3, 7, 64, 2, 3)];
                let stage = |v: &mut Vec<LayerGeom>,
                             blocks: usize,
                             hw: usize,
                             cin: usize,
                             cmid: usize,
                             first_stride: usize| {
                    let mut in_c = cin;
                    let mut cur = hw;
                    for blk in 0..blocks {
                        let s = if blk == 0 { first_stride } else { 1 };
                        // 1×1 reduce (stride on the 3×3 per torchvision).
                        v.push(conv(cur, cur, in_c, 1, cmid, 1, 0));
                        v.push(conv(cur, cur, cmid, 3, cmid, s, 1));
                        if s == 2 {
                            cur /= 2;
                        }
                        v.push(conv(cur, cur, cmid, 1, cmid * 4, 1, 0));
                        in_c = cmid * 4;
                    }
                };
                stage(&mut v, 3, 56, 64, 64, 1);
                stage(&mut v, 4, 56, 256, 128, 2);
                stage(&mut v, 6, 28, 512, 256, 2);
                stage(&mut v, 3, 14, 1024, 512, 2);
                v
            },
            filter_density: 0.421,
            map_density: 0.384,
            per_layer: None,
        },
        Benchmark::InceptionV4 => NetworkSpec {
            benchmark: b,
            // Table 1: "20* (* 2 inception C modules)": two Inception-C
            // modules (8×8 grid, 1536 input channels), 10 convs each.
            layers: {
                let mut v = Vec::new();
                for _ in 0..2 {
                    // branch 1: avgpool → 1×1 256
                    v.push(conv(8, 8, 1536, 1, 256, 1, 0));
                    // branch 2: 1×1 256
                    v.push(conv(8, 8, 1536, 1, 256, 1, 0));
                    // branch 3: 1×1 384 → {1×3 256, 3×1 256}
                    v.push(conv(8, 8, 1536, 1, 384, 1, 0));
                    v.push(conv(8, 8, 384, 3, 256, 1, 1)); // 1×3 ≈ 3 (sep.)
                    v.push(conv(8, 8, 384, 3, 256, 1, 1)); // 3×1
                    // branch 4: 1×1 384 → 3×1 448 → 1×3 512 → {1×3,3×1} 256
                    v.push(conv(8, 8, 1536, 1, 384, 1, 0));
                    v.push(conv(8, 8, 384, 3, 448, 1, 1));
                    v.push(conv(8, 8, 448, 3, 512, 1, 1));
                    v.push(conv(8, 8, 512, 3, 256, 1, 1));
                    v.push(conv(8, 8, 512, 3, 256, 1, 1));
                }
                v
            },
            filter_density: 0.570,
            map_density: 0.317,
            per_layer: None,
        },
        Benchmark::Custom(i) => custom_spec(i),
    }
}

// ---- custom network registry -------------------------------------------

/// One registered user-defined network. Names are leaked to `'static`
/// so `Benchmark::name` keeps its zero-cost signature; the registry is
/// tiny (capped) and lives for the process lifetime anyway.
struct CustomNet {
    name: &'static str,
    /// FNV-1a hash of the canonical spec JSON (cache-key component).
    spec_hash: u64,
    layers: Vec<LayerGeom>,
    filter_density: f64,
    map_density: f64,
    per_layer: Option<Vec<(f64, f64)>>,
    canonical: Json,
}

/// Hard cap on registered customs — a typo'd client loop must not leak
/// unbounded names in a long-lived server. Known limitation: the
/// registry is process-wide and append-only, so on an (unauthenticated)
/// shared server a client can fill it or claim a name first; content
/// hashing in the cache key guarantees a squatted name can never serve
/// wrong *results*, only an explicit registration error.
const CUSTOM_CAP: usize = 1024;

fn registry() -> &'static RwLock<Vec<CustomNet>> {
    static REGISTRY: OnceLock<RwLock<Vec<CustomNet>>> = OnceLock::new();
    REGISTRY.get_or_init(|| RwLock::new(Vec::new()))
}

fn custom_name(i: u16) -> &'static str {
    registry().read().unwrap()[i as usize].name
}

fn custom_spec(i: u16) -> NetworkSpec {
    let reg = registry().read().unwrap();
    let c = &reg[i as usize];
    NetworkSpec {
        benchmark: Benchmark::Custom(i),
        layers: c.layers.clone(),
        filter_density: c.filter_density,
        map_density: c.map_density,
        per_layer: c.per_layer.clone(),
    }
}

/// The canonical JSON a custom network serializes to on the wire
/// (`JobSpec::to_json` embeds it so a remote server can resolve the
/// job without prior registration). `None` for built-ins.
pub fn custom_canonical_json(b: Benchmark) -> Option<Json> {
    match b {
        Benchmark::Custom(i) => {
            Some(registry().read().unwrap()[i as usize].canonical.clone())
        }
        _ => None,
    }
}

fn geom_field(obj: &Json, key: &str) -> Result<usize, String> {
    obj.get(key)
        .and_then(Json::as_usize)
        .ok_or_else(|| format!("layer field '{key}' expects a non-negative integer"))
}

fn density_field(obj: &Json, key: &str) -> Result<Option<f64>, String> {
    match obj.get(key) {
        None => Ok(None),
        Some(v) => {
            let x = v
                .as_f64()
                .ok_or_else(|| format!("'{key}' expects a number"))?;
            if !(0.0..=1.0).contains(&x) {
                return Err(format!("'{key}' = {x} outside [0, 1]"));
            }
            Ok(Some(x))
        }
    }
}

/// Register a user-defined network from its JSON spec:
///
/// ```json
/// {"name": "tiny",
///  "filter_density": 0.4, "map_density": 0.5,
///  "layers": [
///    {"h":14,"w":14,"d":128,"k":3,"n":128,"stride":1,"pad":1}
///  ]}
/// ```
///
/// Per-layer `filter_density`/`map_density` keys may appear on *every*
/// layer instead of the network-average pair (all-or-nothing, so a
/// half-specified profile cannot silently mix with the default one).
/// Unknown keys are errors — the same silent-typo guard as the rest of
/// the stack. Registering the identical spec again returns the same
/// handle; reusing a name for a *different* spec is an error.
pub fn register_custom_network(j: &Json) -> Result<Benchmark, String> {
    let obj = j.as_obj().ok_or("network spec must be a JSON object")?;
    for k in obj.keys() {
        if !matches!(
            k.as_str(),
            "name" | "layers" | "filter_density" | "map_density"
        ) {
            return Err(format!("unknown network spec key '{k}'"));
        }
    }
    let name = j
        .get("name")
        .and_then(Json::as_str)
        .ok_or("network spec missing 'name'")?;
    if name.is_empty() || name.chars().any(|c| c.is_whitespace()) {
        return Err(format!("invalid network name '{name}'"));
    }
    if Benchmark::ALL.iter().any(|b| b.name() == name) {
        return Err(format!("'{name}' is a built-in network name"));
    }
    let layers_json = j
        .get("layers")
        .and_then(Json::as_arr)
        .ok_or("network spec missing 'layers' array")?;
    if layers_json.is_empty() {
        return Err("network spec has no layers".into());
    }

    let mut layers = Vec::with_capacity(layers_json.len());
    let mut per_layer: Vec<(f64, f64)> = Vec::new();
    let mut with_density = 0usize;
    for (i, lj) in layers_json.iter().enumerate() {
        let lobj = lj
            .as_obj()
            .ok_or_else(|| format!("layer {i} must be an object"))?;
        for k in lobj.keys() {
            if !matches!(
                k.as_str(),
                "h" | "w" | "d" | "k" | "n" | "stride" | "pad"
                    | "filter_density" | "map_density"
            ) {
                return Err(format!("layer {i}: unknown key '{k}'"));
            }
        }
        let g = LayerGeom {
            h: geom_field(lj, "h")?,
            w: geom_field(lj, "w")?,
            d: geom_field(lj, "d")?,
            k: geom_field(lj, "k")?,
            n: geom_field(lj, "n")?,
            stride: geom_field(lj, "stride")?,
            pad: geom_field(lj, "pad")?,
        };
        if g.h == 0 || g.w == 0 || g.d == 0 || g.k == 0 || g.n == 0 || g.stride == 0 {
            return Err(format!("layer {i}: zero-sized dimension in {g:?}"));
        }
        if g.h + 2 * g.pad < g.k || g.w + 2 * g.pad < g.k {
            return Err(format!("layer {i}: kernel {} exceeds padded input", g.k));
        }
        let fd = density_field(lj, "filter_density")?;
        let md = density_field(lj, "map_density")?;
        match (fd, md) {
            (Some(f), Some(m)) => {
                with_density += 1;
                per_layer.push((f, m));
            }
            (None, None) => {}
            _ => {
                return Err(format!(
                    "layer {i}: specify both filter_density and map_density or neither"
                ))
            }
        }
        layers.push(g);
    }
    let per_layer = if with_density == layers.len() {
        Some(per_layer)
    } else if with_density == 0 {
        None
    } else {
        return Err(format!(
            "{with_density} of {} layers carry densities — per-layer densities are \
             all-or-nothing",
            layers.len()
        ));
    };

    let net_fd = density_field(j, "filter_density")?;
    let net_md = density_field(j, "map_density")?;
    let (filter_density, map_density) = match &per_layer {
        Some(pl) => {
            if net_fd.is_some() || net_md.is_some() {
                return Err(
                    "specify either per-layer densities or network averages, not both"
                        .into(),
                );
            }
            let n = pl.len() as f64;
            (
                pl.iter().map(|x| x.0).sum::<f64>() / n,
                pl.iter().map(|x| x.1).sum::<f64>() / n,
            )
        }
        None => (
            net_fd.ok_or("network spec missing 'filter_density'")?,
            net_md.ok_or("network spec missing 'map_density'")?,
        ),
    };

    // Canonical form + content hash (the cache-key component). The
    // input object already passed the unknown-key guard, and Json
    // objects are BTreeMaps, so its compact serialization is canonical.
    let canonical = j.clone();
    let spec_hash = crate::util::fnv1a64(
        canonical.to_string().as_bytes(),
        crate::util::FNV_OFFSET_BASIS,
    );

    let mut reg = registry().write().unwrap();
    if let Some(i) = reg.iter().position(|c| c.name == name) {
        return if reg[i].spec_hash == spec_hash {
            Ok(Benchmark::Custom(i as u16))
        } else {
            Err(format!(
                "network '{name}' is already registered with different contents"
            ))
        };
    }
    if reg.len() >= CUSTOM_CAP {
        return Err(format!("custom network registry full ({CUSTOM_CAP})"));
    }
    reg.push(CustomNet {
        name: Box::leak(name.to_string().into_boxed_str()),
        spec_hash,
        layers,
        filter_density,
        map_density,
        per_layer,
        canonical,
    });
    Ok(Benchmark::Custom((reg.len() - 1) as u16))
}

/// Load and register a custom network from a JSON file (the CLI's
/// `--network <file>` path).
pub fn load_network_file(path: &str) -> Result<Benchmark, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
    let j = Json::parse(&text).map_err(|e| format!("{path}: {e}"))?;
    register_custom_network(&j).map_err(|e| format!("{path}: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_counts_match_table1() {
        assert_eq!(network(Benchmark::AlexNet).layers.len(), 5);
        assert_eq!(network(Benchmark::ResNet18).layers.len(), 17);
        assert_eq!(network(Benchmark::InceptionV4).layers.len(), 20);
        assert_eq!(network(Benchmark::VggNet).layers.len(), 13);
        assert_eq!(network(Benchmark::ResNet50).layers.len(), 49);
    }

    #[test]
    fn densities_match_table1() {
        let checks = [
            (Benchmark::AlexNet, 0.368, 0.473),
            (Benchmark::ResNet18, 0.336, 0.486),
            (Benchmark::InceptionV4, 0.570, 0.317),
            (Benchmark::VggNet, 0.334, 0.446),
            (Benchmark::ResNet50, 0.421, 0.384),
        ];
        for (b, f, m) in checks {
            let n = network(b);
            assert_eq!(n.filter_density, f);
            assert_eq!(n.map_density, m);
        }
    }

    #[test]
    fn per_layer_densities_average_to_table1() {
        for b in Benchmark::ALL {
            let n = network(b);
            let d = n.layer_densities();
            let favg = d.iter().map(|x| x.0).sum::<f64>() / d.len() as f64;
            let mavg = d.iter().map(|x| x.1).sum::<f64>() / d.len() as f64;
            assert!(
                (favg - n.filter_density).abs() < 0.01,
                "{b}: filter avg {favg} vs {}",
                n.filter_density
            );
            assert!(
                (mavg - n.map_density).abs() < 0.01,
                "{b}: map avg {mavg} vs {}",
                n.map_density
            );
        }
    }

    #[test]
    fn density_profile_decays_with_depth() {
        let n = network(Benchmark::VggNet);
        let d = n.layer_densities();
        assert!(d.first().unwrap().0 > d.last().unwrap().0);
        assert!(d.first().unwrap().1 > d.last().unwrap().1);
    }

    #[test]
    fn geometry_chains_are_consistent() {
        // Each layer's input depth must equal some producer's output
        // count for the sequential nets (AlexNet, VGG).
        for b in [Benchmark::AlexNet, Benchmark::VggNet] {
            let n = network(b);
            for w in n.layers.windows(2) {
                assert_eq!(
                    w[1].d, w[0].n,
                    "{b}: layer depth mismatch {:?} -> {:?}",
                    w[0], w[1]
                );
            }
        }
    }

    #[test]
    fn resnet50_shapes_flow() {
        let n = network(Benchmark::ResNet50);
        // All 1x1/3x3 layers must have positive output dims.
        for g in &n.layers {
            assert!(g.out_h() > 0 && g.out_w() > 0, "{g:?}");
        }
        // Final stage operates at 7×7×512 mid-channels.
        let last = n.layers.last().unwrap();
        assert_eq!(last.n, 2048);
        assert_eq!(last.out_h(), 7);
    }

    #[test]
    fn vgg_dense_macs_order_of_magnitude() {
        // VGG-16 convs ≈ 15.3 GMACs per image.
        let n = network(Benchmark::VggNet);
        let macs = n.dense_macs(1) as f64;
        assert!(
            (1.4e10..1.6e10).contains(&macs),
            "VGG MACs {macs:.3e} out of expected range"
        );
    }

    #[test]
    fn benchmark_name_roundtrip() {
        for b in Benchmark::ALL {
            assert_eq!(Benchmark::parse(b.name()), Some(b));
        }
    }

    // ---- custom networks (names are unique per test: the registry is
    // process-wide and tests share one process) ----

    fn custom_json(name: &str, per_layer: bool) -> Json {
        let mut layer = Json::obj();
        layer
            .set("h", 14u64)
            .set("w", 14u64)
            .set("d", 128u64)
            .set("k", 3u64)
            .set("n", 64u64)
            .set("stride", 1u64)
            .set("pad", 1u64);
        if per_layer {
            layer.set("filter_density", 0.4).set("map_density", 0.5);
        }
        let mut j = Json::obj();
        j.set("name", name)
            .set("layers", Json::Arr(vec![layer]));
        if !per_layer {
            j.set("filter_density", 0.3).set("map_density", 0.6);
        }
        j
    }

    #[test]
    fn custom_network_registers_and_resolves() {
        let b = register_custom_network(&custom_json("t-basic", false)).unwrap();
        assert_eq!(b.name(), "t-basic");
        assert_eq!(Benchmark::parse("t-basic"), Some(b));
        let spec = network(b);
        assert_eq!(spec.layers.len(), 1);
        assert_eq!(spec.layers[0].n, 64);
        assert!((spec.filter_density - 0.3).abs() < 1e-12);
        // Average-density customs use the standard depth profile.
        assert!(spec.per_layer.is_none());
        assert_eq!(spec.layer_densities().len(), 1);
    }

    #[test]
    fn custom_per_layer_densities_are_exact() {
        let b = register_custom_network(&custom_json("t-perlayer", true)).unwrap();
        let spec = network(b);
        assert_eq!(spec.layer_densities(), vec![(0.4, 0.5)]);
        assert!((spec.filter_density - 0.4).abs() < 1e-12);
        assert!((spec.map_density - 0.5).abs() < 1e-12);
    }

    #[test]
    fn custom_registration_dedups_and_guards_name_conflicts() {
        let a = register_custom_network(&custom_json("t-dedup", false)).unwrap();
        let b = register_custom_network(&custom_json("t-dedup", false)).unwrap();
        assert_eq!(a, b, "identical spec re-registration shares one handle");
        // Same name, different contents: rejected.
        let conflict = register_custom_network(&custom_json("t-dedup", true));
        assert!(conflict.is_err(), "{conflict:?}");
    }

    #[test]
    fn custom_cache_tokens_distinguish_contents() {
        let a = register_custom_network(&custom_json("t-tok-a", false)).unwrap();
        let b = register_custom_network(&custom_json("t-tok-b", true)).unwrap();
        assert_ne!(a.cache_token(), b.cache_token());
        assert!(a.cache_token().starts_with("custom:t-tok-a:"));
        // Built-ins keep their bare names (cache keys unchanged).
        assert_eq!(Benchmark::AlexNet.cache_token(), "alexnet");
    }

    #[test]
    fn custom_spec_validation_rejects_bad_input() {
        // Built-in name collision.
        let mut j = custom_json("alexnet", false);
        assert!(register_custom_network(&j).is_err());
        // Unknown top-level key.
        j = custom_json("t-bad1", false);
        j.set("layerz", 1u64);
        assert!(register_custom_network(&j).unwrap_err().contains("layerz"));
        // Unknown layer key.
        let mut layer = Json::obj();
        layer
            .set("h", 8u64)
            .set("w", 8u64)
            .set("d", 16u64)
            .set("k", 3u64)
            .set("n", 8u64)
            .set("stride", 1u64)
            .set("padd", 1u64);
        let mut j2 = Json::obj();
        j2.set("name", "t-bad2")
            .set("filter_density", 0.5)
            .set("map_density", 0.5)
            .set("layers", Json::Arr(vec![layer]));
        assert!(register_custom_network(&j2).unwrap_err().contains("padd"));
        // Missing densities entirely.
        let mut j3 = custom_json("t-bad3", false);
        if let Json::Obj(m) = &mut j3 {
            m.remove("filter_density");
        }
        assert!(register_custom_network(&j3).is_err());
        // Density out of range.
        let mut j4 = custom_json("t-bad4", false);
        j4.set("map_density", 1.5);
        assert!(register_custom_network(&j4).is_err());
    }

    #[test]
    fn load_network_file_roundtrip() {
        let path = std::env::temp_dir().join("barista_t_load_net.json");
        std::fs::write(&path, custom_json("t-fromfile", true).to_string()).unwrap();
        let b = load_network_file(path.to_str().unwrap()).unwrap();
        assert_eq!(b.name(), "t-fromfile");
        assert!(load_network_file("/no/such/file.json").is_err());
        let _ = std::fs::remove_file(&path);
    }
}
