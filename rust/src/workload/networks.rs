//! Conv-layer tables for the paper's five benchmarks (Table 1).
//!
//! Layer geometries are the standard published architectures; densities
//! are the paper's network averages (filter density from magnitude
//! pruning + retraining [23], input-map density from ReLU statistics),
//! with deterministic per-layer modulation: early layers are denser,
//! deep layers sparser — the universally observed profile (e.g. SparTen
//! Fig. 12, Cnvlutin Table 1) — normalized so the *network average*
//! matches Table 1 exactly.

use crate::tensor::LayerGeom;

/// The five benchmarks of Table 1.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Benchmark {
    AlexNet,
    ResNet18,
    InceptionV4,
    VggNet,
    ResNet50,
}

impl Benchmark {
    /// Ordered by increasing sparsity opportunity, as Figure 7's X axis.
    pub const ALL: [Benchmark; 5] = [
        Benchmark::AlexNet,
        Benchmark::ResNet18,
        Benchmark::InceptionV4,
        Benchmark::VggNet,
        Benchmark::ResNet50,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Benchmark::AlexNet => "alexnet",
            Benchmark::ResNet18 => "resnet18",
            Benchmark::InceptionV4 => "inception-v4",
            Benchmark::VggNet => "vggnet",
            Benchmark::ResNet50 => "resnet50",
        }
    }

    pub fn parse(s: &str) -> Option<Benchmark> {
        Self::ALL.iter().copied().find(|b| b.name() == s)
    }
}

impl std::fmt::Display for Benchmark {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// A benchmark's full conv-layer specification.
#[derive(Debug, Clone)]
pub struct NetworkSpec {
    pub benchmark: Benchmark,
    pub layers: Vec<LayerGeom>,
    /// Network-average filter density (Table 1).
    pub filter_density: f64,
    /// Network-average input-map density (Table 1).
    pub map_density: f64,
}

impl NetworkSpec {
    /// Per-layer (filter, map) densities: a deterministic depth profile
    /// normalized so averages match Table 1. Input maps of layer 0 are
    /// raw images (density ≈ 1.0 conceptually, but the paper reports the
    /// network average including layer 0 — we use the same profile for
    /// simplicity and normalize across all layers).
    pub fn layer_densities(&self) -> Vec<(f64, f64)> {
        profile(self.layers.len(), self.filter_density, self.map_density)
    }

    /// Total dense MACs for a minibatch.
    pub fn dense_macs(&self, batch: usize) -> u64 {
        self.layers.iter().map(|g| g.dense_macs(batch)).sum()
    }
}

/// Depth-decaying density profile with average pinned to `avg`:
/// raw_i = clamp(avg * (1.25 - 0.5 * i/(L-1)), lo, hi), then rescaled.
fn profile(layers: usize, filter_avg: f64, map_avg: f64) -> Vec<(f64, f64)> {
    let shape = |i: usize, avg: f64| -> f64 {
        let t = if layers <= 1 {
            0.5
        } else {
            i as f64 / (layers - 1) as f64
        };
        (avg * (1.25 - 0.5 * t)).clamp(0.02, 0.98)
    };
    let mut fs: Vec<f64> = (0..layers).map(|i| shape(i, filter_avg)).collect();
    let mut ms: Vec<f64> = (0..layers).map(|i| shape(i, map_avg)).collect();
    // Pin the mean exactly (scaling preserves the monotone profile).
    let rescale = |v: &mut Vec<f64>, avg: f64| {
        let mean = v.iter().sum::<f64>() / v.len() as f64;
        let s = avg / mean;
        for x in v.iter_mut() {
            *x = (*x * s).clamp(0.02, 0.98);
        }
    };
    rescale(&mut fs, filter_avg);
    rescale(&mut ms, map_avg);
    fs.into_iter().zip(ms).collect()
}

fn conv(h: usize, w: usize, d: usize, k: usize, n: usize, stride: usize, pad: usize) -> LayerGeom {
    LayerGeom {
        h,
        w,
        d,
        k,
        n,
        stride,
        pad,
    }
}

/// Build the layer table for a benchmark.
pub fn network(b: Benchmark) -> NetworkSpec {
    match b {
        Benchmark::AlexNet => NetworkSpec {
            benchmark: b,
            // The classic 5 conv layers (224×224 ImageNet input).
            layers: vec![
                conv(224, 224, 3, 11, 96, 4, 2),
                conv(27, 27, 96, 5, 256, 1, 2),
                conv(13, 13, 256, 3, 384, 1, 1),
                conv(13, 13, 384, 3, 384, 1, 1),
                conv(13, 13, 384, 3, 256, 1, 1),
            ],
            filter_density: 0.368,
            map_density: 0.473,
        },
        Benchmark::VggNet => NetworkSpec {
            benchmark: b,
            // VGG-16's 13 conv layers.
            layers: vec![
                conv(224, 224, 3, 3, 64, 1, 1),
                conv(224, 224, 64, 3, 64, 1, 1),
                conv(112, 112, 64, 3, 128, 1, 1),
                conv(112, 112, 128, 3, 128, 1, 1),
                conv(56, 56, 128, 3, 256, 1, 1),
                conv(56, 56, 256, 3, 256, 1, 1),
                conv(56, 56, 256, 3, 256, 1, 1),
                conv(28, 28, 256, 3, 512, 1, 1),
                conv(28, 28, 512, 3, 512, 1, 1),
                conv(28, 28, 512, 3, 512, 1, 1),
                conv(14, 14, 512, 3, 512, 1, 1),
                conv(14, 14, 512, 3, 512, 1, 1),
                conv(14, 14, 512, 3, 512, 1, 1),
            ],
            filter_density: 0.334,
            map_density: 0.446,
        },
        Benchmark::ResNet18 => NetworkSpec {
            benchmark: b,
            // conv1 + 8 basic blocks × 2 convs = 17 layers (Table 1).
            layers: {
                let mut v = vec![conv(224, 224, 3, 7, 64, 2, 3)];
                // stage 1: 56×56, 64ch
                for _ in 0..2 {
                    v.push(conv(56, 56, 64, 3, 64, 1, 1));
                    v.push(conv(56, 56, 64, 3, 64, 1, 1));
                }
                // stage 2: first block downsamples 56→28, 64→128
                v.push(conv(56, 56, 64, 3, 128, 2, 1));
                v.push(conv(28, 28, 128, 3, 128, 1, 1));
                v.push(conv(28, 28, 128, 3, 128, 1, 1));
                v.push(conv(28, 28, 128, 3, 128, 1, 1));
                // stage 3: 28→14, 128→256
                v.push(conv(28, 28, 128, 3, 256, 2, 1));
                v.push(conv(14, 14, 256, 3, 256, 1, 1));
                v.push(conv(14, 14, 256, 3, 256, 1, 1));
                v.push(conv(14, 14, 256, 3, 256, 1, 1));
                // stage 4: 14→7, 256→512
                v.push(conv(14, 14, 256, 3, 512, 2, 1));
                v.push(conv(7, 7, 512, 3, 512, 1, 1));
                v.push(conv(7, 7, 512, 3, 512, 1, 1));
                v.push(conv(7, 7, 512, 3, 512, 1, 1));
                v
            },
            filter_density: 0.336,
            map_density: 0.486,
        },
        Benchmark::ResNet50 => NetworkSpec {
            benchmark: b,
            // conv1 + 16 bottleneck blocks × 3 convs = 49 layers.
            layers: {
                let mut v = vec![conv(224, 224, 3, 7, 64, 2, 3)];
                let stage = |v: &mut Vec<LayerGeom>,
                             blocks: usize,
                             hw: usize,
                             cin: usize,
                             cmid: usize,
                             first_stride: usize| {
                    let mut in_c = cin;
                    let mut cur = hw;
                    for blk in 0..blocks {
                        let s = if blk == 0 { first_stride } else { 1 };
                        // 1×1 reduce (stride on the 3×3 per torchvision).
                        v.push(conv(cur, cur, in_c, 1, cmid, 1, 0));
                        v.push(conv(cur, cur, cmid, 3, cmid, s, 1));
                        if s == 2 {
                            cur /= 2;
                        }
                        v.push(conv(cur, cur, cmid, 1, cmid * 4, 1, 0));
                        in_c = cmid * 4;
                    }
                };
                stage(&mut v, 3, 56, 64, 64, 1);
                stage(&mut v, 4, 56, 256, 128, 2);
                stage(&mut v, 6, 28, 512, 256, 2);
                stage(&mut v, 3, 14, 1024, 512, 2);
                v
            },
            filter_density: 0.421,
            map_density: 0.384,
        },
        Benchmark::InceptionV4 => NetworkSpec {
            benchmark: b,
            // Table 1: "20* (* 2 inception C modules)": two Inception-C
            // modules (8×8 grid, 1536 input channels), 10 convs each.
            layers: {
                let mut v = Vec::new();
                for _ in 0..2 {
                    // branch 1: avgpool → 1×1 256
                    v.push(conv(8, 8, 1536, 1, 256, 1, 0));
                    // branch 2: 1×1 256
                    v.push(conv(8, 8, 1536, 1, 256, 1, 0));
                    // branch 3: 1×1 384 → {1×3 256, 3×1 256}
                    v.push(conv(8, 8, 1536, 1, 384, 1, 0));
                    v.push(conv(8, 8, 384, 3, 256, 1, 1)); // 1×3 ≈ 3 (sep.)
                    v.push(conv(8, 8, 384, 3, 256, 1, 1)); // 3×1
                    // branch 4: 1×1 384 → 3×1 448 → 1×3 512 → {1×3,3×1} 256
                    v.push(conv(8, 8, 1536, 1, 384, 1, 0));
                    v.push(conv(8, 8, 384, 3, 448, 1, 1));
                    v.push(conv(8, 8, 448, 3, 512, 1, 1));
                    v.push(conv(8, 8, 512, 3, 256, 1, 1));
                    v.push(conv(8, 8, 512, 3, 256, 1, 1));
                }
                v
            },
            filter_density: 0.570,
            map_density: 0.317,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_counts_match_table1() {
        assert_eq!(network(Benchmark::AlexNet).layers.len(), 5);
        assert_eq!(network(Benchmark::ResNet18).layers.len(), 17);
        assert_eq!(network(Benchmark::InceptionV4).layers.len(), 20);
        assert_eq!(network(Benchmark::VggNet).layers.len(), 13);
        assert_eq!(network(Benchmark::ResNet50).layers.len(), 49);
    }

    #[test]
    fn densities_match_table1() {
        let checks = [
            (Benchmark::AlexNet, 0.368, 0.473),
            (Benchmark::ResNet18, 0.336, 0.486),
            (Benchmark::InceptionV4, 0.570, 0.317),
            (Benchmark::VggNet, 0.334, 0.446),
            (Benchmark::ResNet50, 0.421, 0.384),
        ];
        for (b, f, m) in checks {
            let n = network(b);
            assert_eq!(n.filter_density, f);
            assert_eq!(n.map_density, m);
        }
    }

    #[test]
    fn per_layer_densities_average_to_table1() {
        for b in Benchmark::ALL {
            let n = network(b);
            let d = n.layer_densities();
            let favg = d.iter().map(|x| x.0).sum::<f64>() / d.len() as f64;
            let mavg = d.iter().map(|x| x.1).sum::<f64>() / d.len() as f64;
            assert!(
                (favg - n.filter_density).abs() < 0.01,
                "{b}: filter avg {favg} vs {}",
                n.filter_density
            );
            assert!(
                (mavg - n.map_density).abs() < 0.01,
                "{b}: map avg {mavg} vs {}",
                n.map_density
            );
        }
    }

    #[test]
    fn density_profile_decays_with_depth() {
        let n = network(Benchmark::VggNet);
        let d = n.layer_densities();
        assert!(d.first().unwrap().0 > d.last().unwrap().0);
        assert!(d.first().unwrap().1 > d.last().unwrap().1);
    }

    #[test]
    fn geometry_chains_are_consistent() {
        // Each layer's input depth must equal some producer's output
        // count for the sequential nets (AlexNet, VGG).
        for b in [Benchmark::AlexNet, Benchmark::VggNet] {
            let n = network(b);
            for w in n.layers.windows(2) {
                assert_eq!(
                    w[1].d, w[0].n,
                    "{b}: layer depth mismatch {:?} -> {:?}",
                    w[0], w[1]
                );
            }
        }
    }

    #[test]
    fn resnet50_shapes_flow() {
        let n = network(Benchmark::ResNet50);
        // All 1x1/3x3 layers must have positive output dims.
        for g in &n.layers {
            assert!(g.out_h() > 0 && g.out_w() > 0, "{g:?}");
        }
        // Final stage operates at 7×7×512 mid-channels.
        let last = n.layers.last().unwrap();
        assert_eq!(last.n, 2048);
        assert_eq!(last.out_h(), 7);
    }

    #[test]
    fn vgg_dense_macs_order_of_magnitude() {
        // VGG-16 convs ≈ 15.3 GMACs per image.
        let n = network(Benchmark::VggNet);
        let macs = n.dense_macs(1) as f64;
        assert!(
            (1.4e10..1.6e10).contains(&macs),
            "VGG MACs {macs:.3e} out of expected range"
        );
    }

    #[test]
    fn benchmark_name_roundtrip() {
        for b in Benchmark::ALL {
            assert_eq!(Benchmark::parse(b.name()), Some(b));
        }
    }
}
