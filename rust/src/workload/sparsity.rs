//! Pluggable sparsity-distribution models (scenario engine).
//!
//! BARISTA's mechanisms each absorb a different *shape* of sparsity
//! imbalance — telescoping and coloring absorb bursty feature-map
//! sparsity, snarfing absorbs shared filter fetches, GB-S absorbs
//! inter-filter density spread (paper §3.2–§3.3) — so how zeros are
//! *distributed* matters as much as how many there are. The seed
//! generator emitted exactly one scenario: independent jittered
//! Bernoulli masks. This module turns that into a pluggable
//! [`SparsityModel`]:
//!
//! * [`SparsityModel::Bernoulli`] — the default, **bit-identical** to
//!   the pre-scenario generator (same RNG streams, same draws);
//! * [`SparsityModel::Clustered`] — spatially clustered / bursty
//!   feature-map zeros à la GrateTile's tiled feature maps: window
//!   masks come from a two-state Markov chain with a configurable mean
//!   zero-run length, stressing telescoping and coloring;
//! * [`SparsityModel::ChannelSkew`] — a hot fraction of filters is
//!   much denser than the rest (channel-magnitude pruning skew),
//!   stressing GB-S and round-robin assignment;
//! * [`SparsityModel::BankBalanced`] — Sense-style bank-balanced
//!   structured filter sparsity: every `bank`-cell bank of a filter
//!   holds an *exact* non-zero count, the best case for load balance;
//! * [`SparsityModel::LayerDecay`] — a geometric depth-decaying
//!   density profile (dense early layers, very sparse deep layers)
//!   replacing the mild linear default, stressing per-layer extremes.
//!
//! Every model is deterministic in the workload RNG streams, hits the
//! layer's target density on average, and is identified by a stable
//! canonical spec string (`clustered:16`) that rides through
//! `SimConfig::canonical_json` — so the service's content-addressed
//! cache and the workload memo distinguish scenarios by construction.

use crate::tensor::{MaskMatrix, SparseChunk, CHUNK_BITS};
use crate::util::rng::Pcg32;
use crate::workload::generator::{FILTER_JITTER, WINDOW_JITTER};

/// How zeros are distributed across the synthesized masks.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SparsityModel {
    /// Independent jittered-Bernoulli masks (the seed behaviour).
    Bernoulli,
    /// Spatially clustered window zeros; `run` is the mean zero-run
    /// length in cells (GrateTile-like bursty feature maps).
    Clustered { run: u32 },
    /// `hot_pct` percent of filters run at ~2× density, the rest are
    /// rescaled so the layer average is preserved.
    ChannelSkew { hot_pct: u32 },
    /// Exact non-zero count per `bank` consecutive filter cells
    /// (Sense-style bank-balanced structured pruning).
    BankBalanced { bank: u32 },
    /// Geometric depth decay: the last layer's density target is
    /// `decay_pct`% of the first's (before mean renormalization).
    LayerDecay { decay_pct: u32 },
}

impl SparsityModel {
    /// One representative of each family, at the default parameters —
    /// the scenario axis of `barista report --figure scenarios` and of
    /// the pinned scenario goldens.
    pub const ALL: [SparsityModel; 5] = [
        SparsityModel::Bernoulli,
        SparsityModel::Clustered { run: 16 },
        SparsityModel::ChannelSkew { hot_pct: 25 },
        SparsityModel::BankBalanced { bank: 32 },
        SparsityModel::LayerDecay { decay_pct: 40 },
    ];

    /// Family name (without parameters).
    pub fn family(&self) -> &'static str {
        match self {
            SparsityModel::Bernoulli => "bernoulli",
            SparsityModel::Clustered { .. } => "clustered",
            SparsityModel::ChannelSkew { .. } => "channel-skew",
            SparsityModel::BankBalanced { .. } => "bank-balanced",
            SparsityModel::LayerDecay { .. } => "layer-decay",
        }
    }

    /// Canonical spec string: `family` or `family:param`. This is the
    /// wire/CLI form and the form embedded in `SimConfig::canonical_json`
    /// (hence in every service cache key and workload memo key);
    /// [`parse`](Self::parse) round-trips it exactly.
    pub fn spec(&self) -> String {
        match *self {
            SparsityModel::Bernoulli => "bernoulli".to_string(),
            SparsityModel::Clustered { run } => format!("clustered:{run}"),
            SparsityModel::ChannelSkew { hot_pct } => format!("channel-skew:{hot_pct}"),
            SparsityModel::BankBalanced { bank } => format!("bank-balanced:{bank}"),
            SparsityModel::LayerDecay { decay_pct } => format!("layer-decay:{decay_pct}"),
        }
    }

    /// Parse `family` (default parameter) or `family:param`. Parameters
    /// are range-checked here so an invalid scenario can never reach
    /// generation.
    pub fn parse(s: &str) -> Result<SparsityModel, String> {
        let (family, param) = match s.split_once(':') {
            Some((f, p)) => (f, Some(p)),
            None => (s, None),
        };
        let num = |p: Option<&str>, default: u32| -> Result<u32, String> {
            match p {
                None => Ok(default),
                Some(v) => v
                    .parse()
                    .map_err(|e| format!("sparsity parameter '{v}': {e}")),
            }
        };
        match family {
            "bernoulli" => {
                if param.is_some() {
                    return Err("'bernoulli' takes no parameter".into());
                }
                Ok(SparsityModel::Bernoulli)
            }
            "clustered" => {
                let run = num(param, 16)?;
                if !(1..=256).contains(&run) {
                    return Err(format!("clustered run length {run} outside 1..=256"));
                }
                Ok(SparsityModel::Clustered { run })
            }
            "channel-skew" => {
                let hot_pct = num(param, 25)?;
                if !(1..=99).contains(&hot_pct) {
                    return Err(format!("channel-skew hot percent {hot_pct} outside 1..=99"));
                }
                Ok(SparsityModel::ChannelSkew { hot_pct })
            }
            "bank-balanced" => {
                let bank = num(param, 32)?;
                if !(2..=CHUNK_BITS as u32).contains(&bank) {
                    return Err(format!(
                        "bank-balanced bank size {bank} outside 2..={CHUNK_BITS}"
                    ));
                }
                Ok(SparsityModel::BankBalanced { bank })
            }
            "layer-decay" => {
                let decay_pct = num(param, 40)?;
                if !(1..=100).contains(&decay_pct) {
                    return Err(format!("layer-decay percent {decay_pct} outside 1..=100"));
                }
                Ok(SparsityModel::LayerDecay { decay_pct })
            }
            other => Err(format!(
                "unknown sparsity model '{other}' (known: bernoulli, clustered[:run], \
                 channel-skew[:pct], bank-balanced[:bank], layer-decay[:pct])"
            )),
        }
    }

    /// Per-layer density targets for layer `index` of `layers`. Every
    /// model except `LayerDecay` returns the baseline unchanged
    /// (bit-identical default path); `LayerDecay` builds a geometric
    /// decay renormalized to preserve the mean of the baseline —
    /// callers pass the *network-average* densities, replacing (not
    /// compounding) any default depth profile.
    pub fn depth_profile(&self, fd: f64, md: f64, index: usize, layers: usize) -> (f64, f64) {
        match *self {
            SparsityModel::LayerDecay { decay_pct } => {
                let g = (decay_pct as f64 / 100.0).clamp(0.01, 1.0);
                let l = layers.max(1);
                let t = |i: usize| {
                    if l <= 1 {
                        0.5
                    } else {
                        i as f64 / (l - 1) as f64
                    }
                };
                let mean: f64 =
                    (0..l).map(|i| g.powf(t(i))).sum::<f64>() / l as f64;
                let shape = g.powf(t(index)) / mean;
                (
                    (fd * shape).clamp(0.02, 0.98),
                    (md * shape).clamp(0.02, 0.98),
                )
            }
            _ => (fd, md),
        }
    }

    /// Synthesize a layer's filter masks: `rows` vectors of `vec_len`
    /// cells at mean density `density`. The Bernoulli arm is the exact
    /// seed draw sequence.
    pub fn filter_masks(
        &self,
        rng: &mut Pcg32,
        rows: usize,
        vec_len: usize,
        density: f64,
    ) -> MaskMatrix {
        match *self {
            SparsityModel::ChannelSkew { hot_pct } => {
                skewed_rows(rng, rows, vec_len, density, hot_pct as f64 / 100.0)
            }
            SparsityModel::BankBalanced { bank } => {
                bank_balanced_rows(rng, rows, vec_len, density, bank as usize)
            }
            // Clustering and depth decay reshape windows / the profile,
            // not the filter draw.
            _ => MaskMatrix::random(rng, rows, vec_len, density, FILTER_JITTER),
        }
    }

    /// Synthesize a layer's sampled window masks. The Bernoulli arm is
    /// the exact seed draw sequence.
    pub fn window_masks(
        &self,
        rng: &mut Pcg32,
        rows: usize,
        vec_len: usize,
        density: f64,
    ) -> MaskMatrix {
        match *self {
            SparsityModel::Clustered { run } => {
                clustered_rows(rng, rows, vec_len, density, run as f64)
            }
            _ => MaskMatrix::random(rng, rows, vec_len, density, WINDOW_JITTER),
        }
    }
}

impl std::fmt::Display for SparsityModel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.spec())
    }
}

/// Rows from a two-state (zero/non-zero) Markov chain: mean zero-run
/// length `run`, non-zero-run length chosen so the stationary density is
/// the row's jittered target. Starting state is drawn from the
/// stationary distribution, so no burn-in is needed.
fn clustered_rows(
    rng: &mut Pcg32,
    rows: usize,
    vec_len: usize,
    density: f64,
    run: f64,
) -> MaskMatrix {
    let chunks = crate::util::ceil_div(vec_len as u64, CHUNK_BITS as u64) as usize;
    let mut m = MaskMatrix::zeroed(rows, chunks);
    for r in 0..rows {
        let d = (density * (1.0 + WINDOW_JITTER * rng.gen_normal())).clamp(0.005, 0.995);
        let mut zero_run = run.max(1.0);
        let mut one_run = d / (1.0 - d) * zero_run;
        if one_run < 1.0 {
            // Sparse rows with short requested runs: a state can't dwell
            // under one cell, so lengthen the zero runs instead — the
            // stationary density stays exactly `d` either way.
            one_run = 1.0;
            zero_run = (1.0 - d) / d;
        }
        // Exit probabilities of each state (geometric run lengths).
        let p_leave_zero = (1.0 / zero_run).min(1.0);
        let p_leave_one = (1.0 / one_run).min(1.0);
        let mut on = rng.gen_bool(d);
        let mut mask: u128 = 0;
        for cell in 0..vec_len {
            let bit = cell % CHUNK_BITS;
            if on {
                mask |= 1u128 << bit;
            }
            if bit == CHUNK_BITS - 1 || cell == vec_len - 1 {
                m.set(r, cell / CHUNK_BITS, SparseChunk::new(mask));
                mask = 0;
            }
            let leave = if on { p_leave_one } else { p_leave_zero };
            if rng.gen_bool(leave) {
                on = !on;
            }
        }
    }
    m
}

/// Rows where a `hot` fraction runs at ~2× density and the rest are
/// rescaled to preserve the mean — inter-filter imbalance far beyond
/// the default jitter (what GB-S and round-robin must absorb).
fn skewed_rows(
    rng: &mut Pcg32,
    rows: usize,
    vec_len: usize,
    density: f64,
    hot: f64,
) -> MaskMatrix {
    let chunks = crate::util::ceil_div(vec_len as u64, CHUNK_BITS as u64) as usize;
    // Hot density: ~2× the mean, capped both physically (0.95) and by
    // the mass actually available — a large hot fraction cannot all run
    // at 2× without pushing the cold rows below the floor, which would
    // silently inflate the layer mean.
    let max_hot = ((density - (1.0 - hot) * 0.005) / hot).max(density);
    // `.max(density)` after the 0.95 cap (not `clamp(density, 0.95)`):
    // densities above 0.95 would invert clamp's bounds and panic.
    let d_hot = (density * 2.0).min(0.95).max(density).min(max_hot);
    // Mean-preserving cold density.
    let d_cold = ((density - hot * d_hot) / (1.0 - hot)).clamp(0.005, 0.995);
    let mut m = MaskMatrix::zeroed(rows, chunks);
    for r in 0..rows {
        let d = if rng.gen_bool(hot) { d_hot } else { d_cold };
        for c in 0..chunks {
            let valid = (vec_len - c * CHUNK_BITS).min(CHUNK_BITS);
            m.set(r, c, SparseChunk::random_bernoulli(rng, d).truncate(valid));
        }
    }
    m
}

/// Rows with an *exact* non-zero count in every `bank` consecutive
/// cells (the last bank of a row may be shorter): Sense-style
/// bank-balanced structured sparsity — zero inter-bank variance, the
/// load balancer's best case.
fn bank_balanced_rows(
    rng: &mut Pcg32,
    rows: usize,
    vec_len: usize,
    density: f64,
    bank: usize,
) -> MaskMatrix {
    let chunks = crate::util::ceil_div(vec_len as u64, CHUNK_BITS as u64) as usize;
    let mut m = MaskMatrix::zeroed(rows, chunks);
    let mut row_masks = vec![0u128; chunks];
    for r in 0..rows {
        let d = (density * (1.0 + FILTER_JITTER * rng.gen_normal())).clamp(0.005, 0.995);
        for x in row_masks.iter_mut() {
            *x = 0;
        }
        let mut start = 0usize;
        while start < vec_len {
            let size = bank.min(vec_len - start);
            let nnz = ((d * size as f64).round() as usize).min(size);
            // Floyd's algorithm over the bank's `size` positions.
            let mut chosen: u128 = 0;
            for j in (size - nnz)..size {
                let t = rng.gen_range(j as u32 + 1) as usize;
                if chosen & (1u128 << t) != 0 {
                    chosen |= 1u128 << j;
                } else {
                    chosen |= 1u128 << t;
                }
            }
            for p in 0..size {
                if chosen & (1u128 << p) != 0 {
                    let cell = start + p;
                    row_masks[cell / CHUNK_BITS] |= 1u128 << (cell % CHUNK_BITS);
                }
            }
            start += size;
        }
        for (c, &mask) in row_masks.iter().enumerate() {
            m.set(r, c, SparseChunk::new(mask));
        }
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::run_prop;

    #[test]
    fn spec_parse_roundtrip() {
        for m in SparsityModel::ALL {
            assert_eq!(SparsityModel::parse(&m.spec()).unwrap(), m);
        }
        assert_eq!(
            SparsityModel::parse("clustered").unwrap(),
            SparsityModel::Clustered { run: 16 }
        );
        assert_eq!(
            SparsityModel::parse("bank-balanced:8").unwrap(),
            SparsityModel::BankBalanced { bank: 8 }
        );
        assert!(SparsityModel::parse("bernoulli:3").is_err());
        assert!(SparsityModel::parse("clustered:0").is_err());
        assert!(SparsityModel::parse("channel-skew:100").is_err());
        assert!(SparsityModel::parse("nope").is_err());
    }

    #[test]
    fn bernoulli_draws_identical_to_mask_matrix_random() {
        // The default model must consume the RNG exactly like the seed
        // generator did — bit-identical masks from equal streams.
        let mut a = Pcg32::new(7, 1);
        let mut b = Pcg32::new(7, 1);
        let via_model =
            SparsityModel::Bernoulli.filter_masks(&mut a, 8, 300, 0.4);
        let direct = MaskMatrix::random(&mut b, 8, 300, 0.4, FILTER_JITTER);
        for r in 0..8 {
            for c in 0..via_model.chunks {
                assert_eq!(via_model.get(r, c), direct.get(r, c));
            }
        }
    }

    #[test]
    fn clustered_hits_density_and_clusters() {
        let mut rng = Pcg32::seeded(3);
        let m = clustered_rows(&mut rng, 128, 1024, 0.4, 16.0);
        let d = m.density();
        assert!((d - 0.4).abs() < 0.06, "density {d}");
        // Clustering: adjacent-cell agreement far above the Bernoulli
        // expectation (d² + (1-d)² ≈ 0.52 at d=0.4).
        let mut same = 0u64;
        let mut total = 0u64;
        for r in 0..m.rows {
            for c in 0..m.chunks {
                let mask = m.get(r, c).mask;
                for b in 0..(CHUNK_BITS - 1) {
                    let x = (mask >> b) & 1;
                    let y = (mask >> (b + 1)) & 1;
                    same += (x == y) as u64;
                    total += 1;
                }
            }
        }
        let agree = same as f64 / total as f64;
        assert!(agree > 0.8, "adjacent agreement {agree} not clustered");
    }

    #[test]
    fn bank_balanced_is_exact_per_bank() {
        let mut rng = Pcg32::seeded(4);
        let bank = 32usize;
        let m = bank_balanced_rows(&mut rng, 16, 256, 0.375, bank);
        for r in 0..m.rows {
            // Recover the row's jittered density from its total, then
            // check every bank holds exactly round(d*bank) non-zeros.
            let row_nnz = m.row_nnz(r) as usize;
            let banks = 256 / bank;
            assert_eq!(row_nnz % banks, 0, "row {r}: banks must be equal");
            let per = row_nnz / banks;
            for bidx in 0..banks {
                let mut got = 0usize;
                for p in 0..bank {
                    let cell = bidx * bank + p;
                    let chunk = m.get(r, cell / CHUNK_BITS).mask;
                    got += ((chunk >> (cell % CHUNK_BITS)) & 1) as usize;
                }
                assert_eq!(got, per, "row {r} bank {bidx}");
            }
        }
    }

    #[test]
    fn channel_skew_preserves_mean_and_spreads() {
        let mut rng = Pcg32::seeded(5);
        let m = skewed_rows(&mut rng, 512, 1024, 0.35, 0.25);
        let d = m.density();
        assert!((d - 0.35).abs() < 0.05, "mean density {d}");
        // Hot rows exist: max row density near 0.7, min well below.
        let mut lo = f64::MAX;
        let mut hi = 0.0f64;
        for r in 0..m.rows {
            let rd = m.row_nnz(r) as f64 / 1024.0;
            lo = lo.min(rd);
            hi = hi.max(rd);
        }
        assert!(hi > 0.6, "no hot rows: max {hi}");
        assert!(lo < 0.35, "no cold rows: min {lo}");
    }

    #[test]
    fn layer_decay_profile_decays_and_preserves_mean() {
        let m = SparsityModel::LayerDecay { decay_pct: 40 };
        let layers = 12;
        let mut prev = f64::MAX;
        let mut sum = 0.0;
        for i in 0..layers {
            let (fd, _) = m.depth_profile(0.4, 0.5, i, layers);
            assert!(fd <= prev + 1e-12, "layer {i}: profile must decay");
            prev = fd;
            sum += fd;
        }
        let mean = sum / layers as f64;
        assert!((mean - 0.4).abs() < 0.02, "mean {mean} drifted from 0.4");
        // First layer denser than the base, last much sparser.
        assert!(m.depth_profile(0.4, 0.5, 0, layers).0 > 0.4);
        assert!(m.depth_profile(0.4, 0.5, layers - 1, layers).0 < 0.3);
    }

    #[test]
    fn non_decay_models_leave_profile_untouched() {
        for m in [
            SparsityModel::Bernoulli,
            SparsityModel::Clustered { run: 8 },
            SparsityModel::ChannelSkew { hot_pct: 10 },
            SparsityModel::BankBalanced { bank: 16 },
        ] {
            assert_eq!(m.depth_profile(0.37, 0.51, 3, 9), (0.37, 0.51));
        }
    }

    #[test]
    fn prop_all_models_respect_vec_len_truncation() {
        run_prop("mask truncation", 0x5CEA, 60, |rng| {
            let vec_len = 64 + rng.gen_range(400) as usize;
            let rows = 1 + rng.gen_range(16) as usize;
            let model = SparsityModel::ALL
                [rng.gen_range(SparsityModel::ALL.len() as u32) as usize];
            let f = model.filter_masks(rng, rows, vec_len, 0.5);
            let w = model.window_masks(rng, rows, vec_len, 0.5);
            for m in [&f, &w] {
                let tail_valid = vec_len - (m.chunks - 1) * CHUNK_BITS;
                for r in 0..rows {
                    let tail = m.get(r, m.chunks - 1);
                    if tail_valid < CHUNK_BITS
                        && tail.mask >> tail_valid != 0
                    {
                        return Err(format!(
                            "{model}: bits beyond vec_len {vec_len} in row {r}"
                        ));
                    }
                }
            }
            Ok(())
        });
    }
}
