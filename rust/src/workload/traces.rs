//! Trace ingestion for *measured* sparsity (DESIGN.md §Traces).
//!
//! Every scenario the simulator runs elsewhere is a synthetic draw from
//! a parametric [`SparsityModel`]. This module closes the loop with
//! real networks: a versioned JSON trace carries per-layer measured
//! sparsity — per-channel density samples, a density histogram, or raw
//! block-occupancy rows — and a deterministic fitting step selects the
//! closest existing model parameters per layer (least squares over a
//! mean-relative density histogram plus, when raw occupancy is
//! available, adjacent-cell agreement and sub-block Fano factors), with
//! seeded tie-breaks and reported residuals.
//!
//! A loaded trace becomes an ordinary registered custom network whose
//! per-layer mean densities are pinned *exactly* (the fit never moves
//! the measured means — it only picks the within-layer structure), so
//! it rides every existing path unchanged: `--network`-style cache
//! tokens, `SimConfig::canonical_json`, the workload memo, the service
//! cache key, and the wire protocol's `network_spec` embedding. The
//! registry name is mangled to `<name>@<content-hash>`, so two traces
//! that share a display name but differ in content can never alias — in
//! the in-process registry or in any cache tier.
//!
//! ## Trace format (version 1)
//!
//! ```json
//! {"format": "barista-trace", "version": 1, "name": "pruned-cnn",
//!  "layers": [
//!    {"h": 27, "w": 27, "d": 96, "k": 5, "n": 256, "stride": 1, "pad": 2,
//!     "filter_densities": [0.61, 0.44, 0.52],
//!     "map_hist": [0, 3, 17, 41, 17, 2]}
//!  ]}
//! ```
//!
//! Per layer, each side (filters / feature maps) carries exactly one of:
//!
//! * `*_densities` — measured per-row (per-output-channel / per-window)
//!   densities in `[0, 1]`;
//! * `*_hist` — histogram weights over uniform bins of `[0, 1]`
//!   (≥ 2 bins, any bin count);
//! * `*_occupancy` — raw mask rows as equal-length `'0'`/`'1'` strings
//!   (≥ 64 cells), the richest input: it additionally feeds the
//!   agreement and Fano features, which is what separates clustered /
//!   bank-balanced structure from plain Bernoulli.
//!
//! Unknown keys are errors, same as the rest of the stack.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::tensor::MaskMatrix;
use crate::util::rng::Pcg32;
use crate::util::{fnv1a64, Json, FNV_OFFSET_BASIS};
use crate::workload::networks::{register_custom_network, Benchmark};
use crate::workload::sparsity::SparsityModel;

/// The `format` tag every trace document must carry.
pub const TRACE_FORMAT: &str = "barista-trace";
/// The (only) supported trace format version.
pub const TRACE_VERSION: u64 = 1;
/// Bins of the mean-relative density histogram the fit compares on.
pub const FIT_BINS: usize = 16;
/// Seed of the candidate-synthesis draws. Fixed: fits are a pure
/// function of the trace document, never of call order or wall clock.
pub const FIT_SEED: u64 = 0x712A_CE5D;

/// Probe geometry for candidate synthesis: enough rows/cells that the
/// signature features are stable, small enough that a full fit is
/// milliseconds-scale even in debug builds.
const PROBE_ROWS: usize = 96;
const PROBE_CELLS: usize = 768;

/// Feature weights in the residual: the histogram carries FIT_BINS
/// squared terms, so the scalar features get multipliers to stay
/// influential when occupancy data is present.
const W_AGREE: f64 = 4.0;
const W_FANO: f64 = 2.0;

/// Which mask generator a measured side is compared against (filter
/// draws and window draws use different jitter and different structured
/// families, so the signature synthesis must match).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Side {
    Filter,
    Window,
}

/// Measured data for one side (filters or feature maps) of one layer,
/// reduced to the features the fit compares on.
#[derive(Debug, Clone)]
pub struct SideData {
    /// Mean density over the measured rows — pinned exactly into the
    /// derived network spec.
    pub mean: f64,
    /// Mean-relative per-row density histogram (`x = d / 2·mean`,
    /// clamped into the last bin), normalized to sum 1.
    hist: [f64; FIT_BINS],
    /// Adjacent-cell agreement rate; only from raw occupancy.
    agreement: Option<f64>,
    /// Fano factors of 8- and 32-cell block nonzero counts; only from
    /// raw occupancy.
    fano: Option<(f64, f64)>,
    /// Number of measured rows (or histogram mass) behind the features.
    pub rows: usize,
}

/// One parsed trace layer: raw geometry (validated at registration) and
/// the measured data for both sides.
#[derive(Debug, Clone)]
pub struct TraceLayer {
    /// `[h, w, d, k, n, stride, pad]`, passed through to the derived
    /// network spec.
    pub geom: [usize; 7],
    pub filters: SideData,
    pub maps: SideData,
}

/// A parsed (not yet fitted) trace document.
#[derive(Debug, Clone)]
pub struct Trace {
    pub name: String,
    pub layers: Vec<TraceLayer>,
}

/// The fitted model for one side of one layer, with its residual and
/// the Bernoulli residual on the same data (so "how much structure did
/// the fit actually find" is always reported, never inferred).
#[derive(Debug, Clone, Copy)]
pub struct SideFit {
    pub model: SparsityModel,
    pub residual: f64,
    pub bernoulli_residual: f64,
}

/// Per-layer fit: exact measured mean densities plus the best
/// within-layer structure for each side.
#[derive(Debug, Clone, Copy)]
pub struct LayerFit {
    pub filter_density: f64,
    pub map_density: f64,
    pub filters: SideFit,
    pub windows: SideFit,
}

/// The full fit of a trace: per-layer fits plus the single
/// network-level model (what `--trace` writes into the job's sparsity
/// spec — the side whose best candidate improves most over Bernoulli,
/// summed across layers).
#[derive(Debug, Clone)]
pub struct TraceFit {
    pub layers: Vec<LayerFit>,
    pub model: SparsityModel,
    /// Summed residual of `model`'s side across layers.
    pub residual: f64,
}

/// A trace after parsing, fitting, and registration: an ordinary
/// `Benchmark` handle (custom network with exact per-layer measured
/// densities) plus the fit report.
#[derive(Debug, Clone)]
pub struct LoadedTrace {
    /// Registry handle for the derived network; its cache token embeds
    /// the mangled name, so distinct traces never alias.
    pub benchmark: Benchmark,
    /// The trace's display name, as written in the document.
    pub name: String,
    /// The mangled registry name: `<name>@<8-hex content hash>`.
    pub registered: String,
    /// FNV-1a of the canonical (compact) trace document.
    pub content_hash: u64,
    pub fit: TraceFit,
}

impl LoadedTrace {
    /// Human-readable fit report (`barista info --trace <file>`); also
    /// the content of the self-sealing fit goldens, so everything in it
    /// must be deterministic.
    pub fn describe(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(
            out,
            "trace {} ({} layers, content {:016x})",
            self.name,
            self.fit.layers.len(),
            self.content_hash
        );
        let _ = writeln!(
            out,
            "  registered as {} (cache token {})",
            self.registered,
            self.benchmark.cache_token()
        );
        let _ = writeln!(
            out,
            "  network model: {} (residual {:.4})",
            self.fit.model.spec(),
            self.fit.residual
        );
        for (i, l) in self.fit.layers.iter().enumerate() {
            let _ = writeln!(
                out,
                "  L{i:<3} df {:.4} dm {:.4} | filters {} (res {:.4}, bern {:.4}) | windows {} (res {:.4}, bern {:.4})",
                l.filter_density,
                l.map_density,
                l.filters.model.spec(),
                l.filters.residual,
                l.filters.bernoulli_residual,
                l.windows.model.spec(),
                l.windows.residual,
                l.windows.bernoulli_residual
            );
        }
        out
    }
}

// ---------------------------------------------------------------------
// Parsing
// ---------------------------------------------------------------------

fn geom_field(obj: &Json, i: usize, key: &str) -> Result<usize, String> {
    obj.get(key)
        .and_then(Json::as_usize)
        .ok_or_else(|| format!("layer {i}: field '{key}' expects a non-negative integer"))
}

/// Build the feature set from per-row density samples (optionally
/// weighted — the histogram input path reuses this with bin centers).
fn side_from_samples(samples: &[(f64, f64)]) -> SideData {
    let total: f64 = samples.iter().map(|s| s.1).sum();
    let mean = samples.iter().map(|s| s.0 * s.1).sum::<f64>() / total.max(1e-12);
    let mut hist = [0.0; FIT_BINS];
    for &(d, w) in samples {
        hist[relative_bin(d, mean)] += w;
    }
    for h in &mut hist {
        *h /= total.max(1e-12);
    }
    SideData {
        mean,
        hist,
        agreement: None,
        fano: None,
        rows: samples.len(),
    }
}

/// Map a density to its mean-relative histogram bin: `x = d / 2·mean`,
/// so the histogram shape is density-invariant — a bimodal channel-skew
/// profile looks bimodal at 50% density and at 99.5% sparsity alike,
/// instead of collapsing into the lowest absolute bin.
fn relative_bin(d: f64, mean: f64) -> usize {
    let x = if mean > 0.0 { d / (2.0 * mean) } else { 0.0 };
    ((x * FIT_BINS as f64) as usize).min(FIT_BINS - 1)
}

/// Fano factor (variance / mean) of a pooled count sample; 1.0 for a
/// degenerate sample (Poisson reference — "no information").
fn fano(counts: &[f64]) -> f64 {
    if counts.is_empty() {
        return 1.0;
    }
    let n = counts.len() as f64;
    let mean = counts.iter().sum::<f64>() / n;
    if mean <= 0.0 {
        return 1.0;
    }
    let var = counts.iter().map(|c| (c - mean) * (c - mean)).sum::<f64>() / n;
    var / mean
}

/// The shared feature extraction over explicit bit rows — used for
/// measured occupancy and for synthesized candidate matrices, so both
/// sides of every comparison go through identical arithmetic.
fn features_from_bits(rows: &[Vec<bool>]) -> SideData {
    let cells = rows.first().map(|r| r.len()).unwrap_or(0);
    let mut densities = Vec::with_capacity(rows.len());
    let mut agree = 0u64;
    let mut pairs = 0u64;
    let mut counts8: Vec<f64> = Vec::new();
    let mut counts32: Vec<f64> = Vec::new();
    for row in rows {
        let nnz = row.iter().filter(|&&b| b).count();
        densities.push(nnz as f64 / cells.max(1) as f64);
        for w in row.windows(2) {
            pairs += 1;
            if w[0] == w[1] {
                agree += 1;
            }
        }
        for block in row.chunks_exact(8) {
            counts8.push(block.iter().filter(|&&b| b).count() as f64);
        }
        for block in row.chunks_exact(32) {
            counts32.push(block.iter().filter(|&&b| b).count() as f64);
        }
    }
    let samples: Vec<(f64, f64)> = densities.iter().map(|&d| (d, 1.0)).collect();
    let mut side = side_from_samples(&samples);
    side.agreement = Some(if pairs > 0 {
        agree as f64 / pairs as f64
    } else {
        1.0
    });
    side.fano = Some((fano(&counts8), fano(&counts32)));
    side.rows = rows.len();
    side
}

/// Parse one side of one layer: exactly one of `<p>_densities`,
/// `<p>_hist`, `<p>_occupancy` (where `<p>` is `filter` or `map`).
fn parse_side(lj: &Json, i: usize, prefix: &str) -> Result<SideData, String> {
    let dens_key = format!("{prefix}_densities");
    let hist_key = format!("{prefix}_hist");
    let occ_key = format!("{prefix}_occupancy");
    let present = [&dens_key, &hist_key, &occ_key]
        .iter()
        .filter(|k| lj.get(k).is_some())
        .count();
    if present != 1 {
        return Err(format!(
            "layer {i}: expected exactly one of '{dens_key}', '{hist_key}', \
             '{occ_key}' (found {present})"
        ));
    }
    if let Some(v) = lj.get(&dens_key) {
        let arr = v
            .as_arr()
            .ok_or_else(|| format!("layer {i}: '{dens_key}' expects an array"))?;
        if arr.is_empty() {
            return Err(format!("layer {i}: '{dens_key}' is empty"));
        }
        let mut samples = Vec::with_capacity(arr.len());
        for (j, x) in arr.iter().enumerate() {
            let d = x
                .as_f64()
                .ok_or_else(|| format!("layer {i}: '{dens_key}[{j}]' expects a number"))?;
            if !(0.0..=1.0).contains(&d) {
                return Err(format!("layer {i}: '{dens_key}[{j}]' = {d} outside [0, 1]"));
            }
            samples.push((d, 1.0));
        }
        return Ok(side_from_samples(&samples));
    }
    if let Some(v) = lj.get(&hist_key) {
        let arr = v
            .as_arr()
            .ok_or_else(|| format!("layer {i}: '{hist_key}' expects an array"))?;
        if arr.len() < 2 {
            return Err(format!(
                "layer {i}: '{hist_key}' needs >= 2 uniform bins over [0, 1]"
            ));
        }
        let mut samples = Vec::with_capacity(arr.len());
        let mut total = 0.0;
        for (j, x) in arr.iter().enumerate() {
            let w = x
                .as_f64()
                .ok_or_else(|| format!("layer {i}: '{hist_key}[{j}]' expects a number"))?;
            if !w.is_finite() || w < 0.0 {
                return Err(format!(
                    "layer {i}: '{hist_key}[{j}]' = {w} must be a finite weight >= 0"
                ));
            }
            let center = (j as f64 + 0.5) / arr.len() as f64;
            samples.push((center, w));
            total += w;
        }
        if total <= 0.0 {
            return Err(format!("layer {i}: '{hist_key}' has zero total weight"));
        }
        return Ok(side_from_samples(&samples));
    }
    let arr = lj
        .get(&occ_key)
        .and_then(Json::as_arr)
        .ok_or_else(|| format!("layer {i}: '{occ_key}' expects an array of strings"))?;
    if arr.is_empty() {
        return Err(format!("layer {i}: '{occ_key}' is empty"));
    }
    let mut rows: Vec<Vec<bool>> = Vec::with_capacity(arr.len());
    let mut cells = 0usize;
    for (j, x) in arr.iter().enumerate() {
        let s = x
            .as_str()
            .ok_or_else(|| format!("layer {i}: '{occ_key}[{j}]' expects a string"))?;
        let mut bits = Vec::with_capacity(s.len());
        for c in s.chars() {
            match c {
                '0' => bits.push(false),
                '1' => bits.push(true),
                other => {
                    return Err(format!(
                        "layer {i}: '{occ_key}[{j}]' contains '{other}' (only '0'/'1')"
                    ))
                }
            }
        }
        if j == 0 {
            cells = bits.len();
            if cells < 64 {
                return Err(format!(
                    "layer {i}: '{occ_key}' rows need >= 64 cells, got {cells}"
                ));
            }
        } else if bits.len() != cells {
            return Err(format!(
                "layer {i}: '{occ_key}[{j}]' length {} != row 0 length {cells}",
                bits.len()
            ));
        }
        rows.push(bits);
    }
    Ok(features_from_bits(&rows))
}

/// Parse a trace document (strict: unknown keys, bad versions, and
/// malformed measurements are all errors, never silent defaults).
pub fn parse_trace(j: &Json) -> Result<Trace, String> {
    let obj = j.as_obj().ok_or("trace must be a JSON object")?;
    for k in obj.keys() {
        if !matches!(
            k.as_str(),
            "format" | "version" | "name" | "description" | "layers"
        ) {
            return Err(format!("unknown trace key '{k}'"));
        }
    }
    match j.get("format").and_then(Json::as_str) {
        Some(TRACE_FORMAT) => {}
        Some(other) => return Err(format!("'format' = '{other}', expected '{TRACE_FORMAT}'")),
        None => return Err(format!("trace missing 'format' (expected '{TRACE_FORMAT}')")),
    }
    match j.get("version").and_then(Json::as_u64) {
        Some(TRACE_VERSION) => {}
        Some(v) => return Err(format!("trace version {v} unsupported (expected {TRACE_VERSION})")),
        None => return Err("trace missing integer 'version'".into()),
    }
    let name = j
        .get("name")
        .and_then(Json::as_str)
        .ok_or("trace missing 'name'")?;
    if name.is_empty() || name.chars().any(|c| c.is_whitespace()) {
        return Err(format!("invalid trace name '{name}'"));
    }
    let layers_json = j
        .get("layers")
        .and_then(Json::as_arr)
        .ok_or("trace missing 'layers' array")?;
    if layers_json.is_empty() {
        return Err("trace has no layers".into());
    }
    let mut layers = Vec::with_capacity(layers_json.len());
    for (i, lj) in layers_json.iter().enumerate() {
        let lobj = lj
            .as_obj()
            .ok_or_else(|| format!("layer {i} must be an object"))?;
        for k in lobj.keys() {
            if !matches!(
                k.as_str(),
                "h" | "w"
                    | "d"
                    | "k"
                    | "n"
                    | "stride"
                    | "pad"
                    | "filter_densities"
                    | "filter_hist"
                    | "filter_occupancy"
                    | "map_densities"
                    | "map_hist"
                    | "map_occupancy"
            ) {
                return Err(format!("layer {i}: unknown key '{k}'"));
            }
        }
        let geom = [
            geom_field(lj, i, "h")?,
            geom_field(lj, i, "w")?,
            geom_field(lj, i, "d")?,
            geom_field(lj, i, "k")?,
            geom_field(lj, i, "n")?,
            geom_field(lj, i, "stride")?,
            geom_field(lj, i, "pad")?,
        ];
        layers.push(TraceLayer {
            geom,
            filters: parse_side(lj, i, "filter")?,
            maps: parse_side(lj, i, "map")?,
        });
    }
    Ok(Trace {
        name: name.to_string(),
        layers,
    })
}

// ---------------------------------------------------------------------
// Fitting
// ---------------------------------------------------------------------

/// Filter-side candidate grid. Index 0 MUST be Bernoulli (the fit
/// reports every candidate's improvement against it).
fn filter_candidates() -> Vec<SparsityModel> {
    let mut v = vec![SparsityModel::Bernoulli];
    for hot_pct in [10, 25, 50, 75] {
        v.push(SparsityModel::ChannelSkew { hot_pct });
    }
    // bank=128 is deliberately absent: at the probe geometry it is
    // statistically indistinguishable from Bernoulli, so keeping it
    // would only add tie-break noise.
    for bank in [4, 8, 16, 32, 64] {
        v.push(SparsityModel::BankBalanced { bank });
    }
    v
}

/// Window-side candidate grid. Index 0 MUST be Bernoulli. run=2 is
/// deliberately absent (its Markov chain is exactly independent at
/// d = 0.5, i.e. Bernoulli by another name).
fn window_candidates() -> Vec<SparsityModel> {
    let mut v = vec![SparsityModel::Bernoulli];
    for run in [4, 8, 16, 32, 64, 128, 256] {
        v.push(SparsityModel::Clustered { run });
    }
    v
}

/// The synthesized signature of one candidate at one (quantized)
/// density: the same features `features_from_bits` extracts, drawn from
/// the candidate's actual mask generator at a fixed probe geometry with
/// a fixed seed — so the whole fit is deterministic.
fn synth_signature(model: &SparsityModel, side: Side, mille: u32) -> SideData {
    let d = f64::from(mille) / 1000.0;
    let tag = format!(
        "{}|{}|{mille}",
        model.spec(),
        if side == Side::Filter { "f" } else { "w" }
    );
    let mut rng = Pcg32::new(FIT_SEED, fnv1a64(tag.as_bytes(), FNV_OFFSET_BASIS));
    let m = match side {
        Side::Filter => model.filter_masks(&mut rng, PROBE_ROWS, PROBE_CELLS, d),
        Side::Window => model.window_masks(&mut rng, PROBE_ROWS, PROBE_CELLS, d),
    };
    features_from_bits(&matrix_bits(&m, PROBE_ROWS, PROBE_CELLS))
}

/// Expand a `MaskMatrix` into explicit bit rows (probe geometry only —
/// this is fit-time code, not the simulator hot path).
fn matrix_bits(m: &MaskMatrix, rows: usize, cells: usize) -> Vec<Vec<bool>> {
    let mut out = Vec::with_capacity(rows);
    for r in 0..rows {
        let mut bits = Vec::with_capacity(cells);
        let mut c = 0usize;
        while bits.len() < cells {
            let mask = m.get(r, c).mask;
            let lim = (cells - bits.len()).min(128);
            for b in 0..lim {
                bits.push((mask >> b) & 1 == 1);
            }
            c += 1;
        }
        out.push(bits);
    }
    out
}

/// Weighted squared distance between a measured side and a candidate
/// signature. The histogram term is always present; agreement and Fano
/// terms only when the trace carried raw occupancy.
fn distance(meas: &SideData, cand: &SideData) -> f64 {
    let mut sse = 0.0;
    for (a, b) in meas.hist.iter().zip(cand.hist.iter()) {
        sse += (a - b) * (a - b);
    }
    if let (Some(a), Some(b)) = (meas.agreement, cand.agreement) {
        sse += W_AGREE * (a - b) * (a - b);
    }
    if let (Some((a8, a32)), Some((b8, b32))) = (meas.fano, cand.fano) {
        let g8 = (a8 - b8) / (a8.abs() + b8.abs() + 1e-9);
        let g32 = (a32 - b32) / (a32.abs() + b32.abs() + 1e-9);
        sse += W_FANO * (g8 * g8 + g32 * g32);
    }
    sse
}

/// Deterministic argmin: smallest residual by `total_cmp`, ties broken
/// by spec-string order (so a fit never depends on grid ordering).
fn argmin_idx(cands: &[SparsityModel], dist: &[f64]) -> usize {
    let mut best = 0usize;
    for i in 1..dist.len() {
        match dist[i].total_cmp(&dist[best]) {
            std::cmp::Ordering::Less => best = i,
            std::cmp::Ordering::Equal if cands[i].spec() < cands[best].spec() => best = i,
            _ => {}
        }
    }
    best
}

type SigMemo = BTreeMap<(String, u8, u32), SideData>;

fn fit_side(
    data: &SideData,
    cands: &[SparsityModel],
    side: Side,
    memo: &mut SigMemo,
) -> (SideFit, Vec<f64>) {
    // Quantize the synthesis density so layers with near-identical
    // means share one memoized signature draw.
    let mille = ((data.mean * 1000.0).round() as u32).clamp(5, 995);
    let mut dist = Vec::with_capacity(cands.len());
    for c in cands {
        let key = (c.spec(), side as u8, mille);
        let sig = memo
            .entry(key)
            .or_insert_with(|| synth_signature(c, side, mille));
        dist.push(distance(data, sig));
    }
    let best = argmin_idx(cands, &dist);
    (
        SideFit {
            model: cands[best],
            residual: dist[best],
            bernoulli_residual: dist[0],
        },
        dist,
    )
}

/// Fit a parsed trace: per-layer per-side least-squares over the
/// candidate grids, then one network-level model — the side (filters vs
/// windows) whose best aggregate candidate improves most over
/// Bernoulli. `LayerDecay` never appears as a candidate: with per-layer
/// means pinned exactly in the derived spec, it is equivalent to
/// Bernoulli within a layer (its whole effect is the depth profile the
/// pinned means already carry).
pub fn fit_trace(trace: &Trace) -> TraceFit {
    let fil_c = filter_candidates();
    let win_c = window_candidates();
    debug_assert!(matches!(fil_c[0], SparsityModel::Bernoulli));
    debug_assert!(matches!(win_c[0], SparsityModel::Bernoulli));
    let mut memo = SigMemo::new();
    let mut fil_tot = vec![0.0f64; fil_c.len()];
    let mut win_tot = vec![0.0f64; win_c.len()];
    let mut layers = Vec::with_capacity(trace.layers.len());
    for l in &trace.layers {
        let (ff, fd) = fit_side(&l.filters, &fil_c, Side::Filter, &mut memo);
        let (wf, wd) = fit_side(&l.maps, &win_c, Side::Window, &mut memo);
        for (t, d) in fil_tot.iter_mut().zip(&fd) {
            *t += d;
        }
        for (t, d) in win_tot.iter_mut().zip(&wd) {
            *t += d;
        }
        layers.push(LayerFit {
            filter_density: l.filters.mean,
            map_density: l.maps.mean,
            filters: ff,
            windows: wf,
        });
    }
    let fi = argmin_idx(&fil_c, &fil_tot);
    let wi = argmin_idx(&win_c, &win_tot);
    let fil_gain = fil_tot[0] - fil_tot[fi];
    let win_gain = win_tot[0] - win_tot[wi];
    let (model, residual) = if matches!(fil_c[fi], SparsityModel::Bernoulli)
        && matches!(win_c[wi], SparsityModel::Bernoulli)
    {
        (SparsityModel::Bernoulli, fil_tot[0].min(win_tot[0]))
    } else if win_gain > fil_gain {
        (win_c[wi], win_tot[wi])
    } else {
        (fil_c[fi], fil_tot[fi])
    };
    TraceFit {
        layers,
        model,
        residual,
    }
}

// ---------------------------------------------------------------------
// Loading (parse + fit + register)
// ---------------------------------------------------------------------

/// Parse, fit, and register a trace document. The derived network spec
/// pins the exact measured per-layer mean densities; the registry name
/// is `<name>@<8-hex content hash>`, so same-name-different-content
/// traces get distinct registry entries and distinct cache tokens, and
/// the identical document loads to the identical handle (dedup).
pub fn load_trace_json(j: &Json) -> Result<LoadedTrace, String> {
    let trace = parse_trace(j)?;
    let content_hash = fnv1a64(j.to_string().as_bytes(), FNV_OFFSET_BASIS);
    let fit = fit_trace(&trace);
    let registered = format!(
        "{}@{:08x}",
        trace.name,
        (content_hash ^ (content_hash >> 32)) as u32
    );
    let mut layer_arr = Vec::with_capacity(trace.layers.len());
    for (l, lf) in trace.layers.iter().zip(&fit.layers) {
        let [h, w, d, k, n, stride, pad] = l.geom;
        let mut lj = Json::obj();
        lj.set("h", h)
            .set("w", w)
            .set("d", d)
            .set("k", k)
            .set("n", n)
            .set("stride", stride)
            .set("pad", pad)
            .set("filter_density", lf.filter_density)
            .set("map_density", lf.map_density);
        layer_arr.push(lj);
    }
    let mut spec = Json::obj();
    spec.set("name", registered.as_str())
        .set("layers", Json::Arr(layer_arr));
    let benchmark =
        register_custom_network(&spec).map_err(|e| format!("trace '{}': {e}", trace.name))?;
    Ok(LoadedTrace {
        benchmark,
        name: trace.name,
        registered,
        content_hash,
        fit,
    })
}

/// Load a trace from a JSON file (the CLI's `--trace <file>` path).
pub fn load_trace_file(path: &str) -> Result<LoadedTrace, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
    let j = Json::parse(&text).map_err(|e| format!("{path}: {e}"))?;
    load_trace_json(&j).map_err(|e| format!("{path}: {e}"))
}

// ---------------------------------------------------------------------
// Synthesis (the round-trip harness)
// ---------------------------------------------------------------------

/// Fabricate a trace document by sampling a [`SparsityModel`] — the
/// round-trip harness of the fitting step (synthesize → fit must
/// recover the generator, tests/trace_goldens.rs) and a convenient way
/// to produce inputs when no profiler is at hand. Layer geometry is a
/// fixed small conv; per-layer mean densities follow the model's depth
/// profile, so `LayerDecay` round-trips through the measured means.
/// `cells` must be >= 64 (the occupancy minimum).
pub fn synthesize_trace_json(
    name: &str,
    model: SparsityModel,
    filter_density: f64,
    map_density: f64,
    layers: usize,
    rows: usize,
    cells: usize,
    seed: u64,
) -> Json {
    let mut layer_arr = Vec::with_capacity(layers);
    for i in 0..layers {
        let (fd, md) = model.depth_profile(filter_density, map_density, i, layers);
        let mut frng = Pcg32::new(seed ^ 0x7F17, i as u64 * 2 + 1);
        let fm = model.filter_masks(&mut frng, rows, cells, fd);
        let mut wrng = Pcg32::new(seed ^ 0x7F17, i as u64 * 2 + 2);
        let wm = model.window_masks(&mut wrng, rows, cells, md);
        let mut lj = Json::obj();
        lj.set("h", 14usize)
            .set("w", 14usize)
            .set("d", 64usize)
            .set("k", 3usize)
            .set("n", 64usize)
            .set("stride", 1usize)
            .set("pad", 1usize)
            .set("filter_occupancy", occupancy_json(&fm, rows, cells))
            .set("map_occupancy", occupancy_json(&wm, rows, cells));
        layer_arr.push(lj);
    }
    let mut j = Json::obj();
    j.set("format", TRACE_FORMAT)
        .set("version", TRACE_VERSION)
        .set("name", name)
        .set("layers", Json::Arr(layer_arr));
    j
}

fn occupancy_json(m: &MaskMatrix, rows: usize, cells: usize) -> Json {
    let bits = matrix_bits(m, rows, cells);
    Json::Arr(
        bits.iter()
            .map(|row| {
                Json::Str(row.iter().map(|&b| if b { '1' } else { '0' }).collect())
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn synth(name: &str, model: SparsityModel, d: f64, seed: u64) -> Json {
        synthesize_trace_json(name, model, 0.4, d, 1, 48, 512, seed)
    }

    #[test]
    fn parse_rejects_malformed_documents() {
        let good = synth("t-parse", SparsityModel::Bernoulli, 0.4, 1);
        assert!(parse_trace(&good).is_ok());

        let mut j = good.clone();
        j.set("bogus", 1u64);
        assert!(parse_trace(&j).unwrap_err().contains("unknown trace key"));

        let mut j = good.clone();
        j.set("format", "not-a-trace");
        assert!(parse_trace(&j).unwrap_err().contains("'format'"));

        let mut j = good.clone();
        j.set("version", 2u64);
        assert!(parse_trace(&j).unwrap_err().contains("version 2"));

        let mut j = good.clone();
        j.set("name", "has space");
        assert!(parse_trace(&j).unwrap_err().contains("invalid trace name"));

        let mut j = good.clone();
        j.set("layers", Json::Arr(vec![]));
        assert!(parse_trace(&j).unwrap_err().contains("no layers"));
    }

    #[test]
    fn parse_rejects_bad_side_data() {
        // Two kinds of measurement on the same side.
        let mut lj = Json::obj();
        lj.set("h", 14usize)
            .set("w", 14usize)
            .set("d", 64usize)
            .set("k", 3usize)
            .set("n", 64usize)
            .set("stride", 1usize)
            .set("pad", 1usize)
            .set("filter_densities", Json::Arr(vec![Json::Num(0.5)]))
            .set("filter_hist", Json::Arr(vec![Json::Num(1.0), Json::Num(1.0)]))
            .set("map_densities", Json::Arr(vec![Json::Num(0.5)]));
        let mut j = Json::obj();
        j.set("format", TRACE_FORMAT)
            .set("version", TRACE_VERSION)
            .set("name", "t-bad")
            .set("layers", Json::Arr(vec![lj]));
        assert!(parse_trace(&j).unwrap_err().contains("exactly one of"));

        // Ragged occupancy rows.
        let mut lj = Json::obj();
        lj.set("h", 14usize)
            .set("w", 14usize)
            .set("d", 64usize)
            .set("k", 3usize)
            .set("n", 64usize)
            .set("stride", 1usize)
            .set("pad", 1usize)
            .set(
                "filter_occupancy",
                Json::Arr(vec![
                    Json::Str("01".repeat(32)),
                    Json::Str("01".repeat(16)),
                ]),
            )
            .set("map_densities", Json::Arr(vec![Json::Num(0.5)]));
        let mut j = Json::obj();
        j.set("format", TRACE_FORMAT)
            .set("version", TRACE_VERSION)
            .set("name", "t-ragged")
            .set("layers", Json::Arr(vec![lj]));
        assert!(parse_trace(&j).unwrap_err().contains("length"));

        // Density out of range.
        let mut lj = Json::obj();
        lj.set("h", 14usize)
            .set("w", 14usize)
            .set("d", 64usize)
            .set("k", 3usize)
            .set("n", 64usize)
            .set("stride", 1usize)
            .set("pad", 1usize)
            .set("filter_densities", Json::Arr(vec![Json::Num(1.5)]))
            .set("map_densities", Json::Arr(vec![Json::Num(0.5)]));
        let mut j = Json::obj();
        j.set("format", TRACE_FORMAT)
            .set("version", TRACE_VERSION)
            .set("name", "t-range")
            .set("layers", Json::Arr(vec![lj]));
        assert!(parse_trace(&j).unwrap_err().contains("outside [0, 1]"));
    }

    #[test]
    fn relative_histogram_is_density_invariant() {
        // Same relative spread at ~40% density and at ~99% sparsity
        // lands in the same bins — the spiking regime must not collapse
        // into bin 0. (Sample values are chosen off the bin boundaries
        // so float rounding cannot flip a bin.)
        let dense: Vec<(f64, f64)> = vec![(0.21, 1.0), (0.34, 1.0), (0.66, 1.0)];
        let sparse: Vec<(f64, f64)> = vec![(0.0042, 1.0), (0.0068, 1.0), (0.0132, 1.0)];
        let a = side_from_samples(&dense);
        let b = side_from_samples(&sparse);
        assert_eq!(a.hist, b.hist, "relative hist must ignore the scale");
        assert!(a.hist[0] < 1e-12, "spread must not collapse into bin 0");
    }

    #[test]
    fn fit_is_deterministic() {
        let j = synth("t-det", SparsityModel::Clustered { run: 32 }, 0.45, 3);
        let t = parse_trace(&j).unwrap();
        let a = fit_trace(&t);
        let b = fit_trace(&t);
        assert_eq!(a.model.spec(), b.model.spec());
        assert_eq!(a.residual.to_bits(), b.residual.to_bits());
        for (x, y) in a.layers.iter().zip(&b.layers) {
            assert_eq!(x.filters.residual.to_bits(), y.filters.residual.to_bits());
            assert_eq!(x.windows.residual.to_bits(), y.windows.residual.to_bits());
        }
    }

    #[test]
    fn clustered_window_structure_is_recovered() {
        let j = synth("t-clust", SparsityModel::Clustered { run: 32 }, 0.45, 5);
        let lt = load_trace_json(&j).unwrap();
        assert_eq!(
            lt.fit.model.family(),
            "clustered",
            "expected a clustered fit, got {} (residual {})",
            lt.fit.model.spec(),
            lt.fit.residual
        );
        // The fit must beat Bernoulli decisively on the window side.
        let l = &lt.fit.layers[0];
        assert!(
            l.windows.residual < l.windows.bernoulli_residual,
            "clustered fit {} not better than bernoulli {}",
            l.windows.residual,
            l.windows.bernoulli_residual
        );
    }

    #[test]
    fn identical_content_dedups_to_one_handle() {
        let j = synth("t-dedup", SparsityModel::Bernoulli, 0.4, 7);
        let a = load_trace_json(&j).unwrap();
        let b = load_trace_json(&j).unwrap();
        assert_eq!(a.benchmark, b.benchmark);
        assert_eq!(a.registered, b.registered);
        assert_eq!(a.benchmark.cache_token(), b.benchmark.cache_token());
    }

    #[test]
    fn same_name_different_content_never_aliases() {
        let a = load_trace_json(&synth("t-alias", SparsityModel::Bernoulli, 0.40, 11)).unwrap();
        let b = load_trace_json(&synth("t-alias", SparsityModel::Bernoulli, 0.41, 12)).unwrap();
        assert_ne!(a.content_hash, b.content_hash);
        assert_ne!(a.registered, b.registered, "mangled names must differ");
        assert_ne!(a.benchmark, b.benchmark);
        assert_ne!(
            a.benchmark.cache_token(),
            b.benchmark.cache_token(),
            "distinct traces must never share a cache identity"
        );
    }

    #[test]
    fn measured_means_are_pinned_exactly() {
        let j = synth("t-pin", SparsityModel::Bernoulli, 0.5, 13);
        let t = parse_trace(&j).unwrap();
        let lt = load_trace_json(&j).unwrap();
        let spec = crate::workload::networks::network(lt.benchmark);
        let per = spec.layer_densities();
        assert_eq!(per.len(), t.layers.len());
        for ((fd, md), l) in per.iter().zip(&t.layers) {
            assert_eq!(fd.to_bits(), l.filters.mean.to_bits());
            assert_eq!(md.to_bits(), l.maps.mean.to_bits());
        }
    }
}
