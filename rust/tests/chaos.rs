//! Seeded chaos harness: scripted wire-fault plans against a live
//! 3-node cluster (`cargo test --release --test chaos --features chaos`,
//! or `make test-chaos`).
//!
//! Every test boots real store-backed worker nodes on ephemeral ports,
//! points a router at them, installs a [`FaultPlan`] keyed on
//! `FAULT_SEED` (default below; CI rotates it nightly), and drives
//! dispatches through the *production* transport — faults are injected
//! inside `Transport::attempt_once`, not mocked around it. The
//! invariants, for **any** seed:
//!
//! * every dispatch returns a frame — byte-identical to a direct
//!   `run_one` when `ok:true`, a structured `degraded` error otherwise;
//!   never a hang (each test runs under a watchdog), never a panic;
//! * exact counter accounting: injected drops == transport
//!   `connect_errors`, injected black holes == `timeouts`, injected
//!   truncations == `protocol_errors`; delays and duplicates produce
//!   no errors at all.
//!
//! Reproduce a failed nightly run with
//! `FAULT_SEED=<seed from the CI log> make test-chaos`.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use barista::cluster::fault::{FaultKind, FaultPlan};
use barista::cluster::{
    HashRing, NodeId, Route, Router, RouterConfig, RouterServer, TransportPolicy,
};
use barista::config::{ArchKind, SimConfig};
use barista::coordinator::{run_one, RunRequest};
use barista::service::{job_key, Client, JobSpec, Priority, QoS, SchedulerConfig, Server, Store};
use barista::util::stats::percentile;
use barista::util::{scratch_dir, Json};
use barista::workload::Benchmark;

type NodeHandle = std::thread::JoinHandle<std::io::Result<()>>;

/// The plan seed: `FAULT_SEED` env (CI nightly rotates it) or a fixed
/// default so plain `make test-chaos` is reproducible.
fn fault_seed() -> u64 {
    match std::env::var("FAULT_SEED") {
        Err(_) => 0xBA12_157A,
        Ok(s) => s
            .parse()
            .unwrap_or_else(|e| panic!("FAULT_SEED='{s}' must be a decimal integer: {e}")),
    }
}

/// Abort the whole process if a chaos scenario wedges: "never hangs" is
/// an assertion here, not a hope. Disarmed on drop.
struct Watchdog {
    armed: Arc<AtomicBool>,
}

impl Watchdog {
    fn arm(tag: &'static str, limit: Duration) -> Watchdog {
        let armed = Arc::new(AtomicBool::new(true));
        let flag = armed.clone();
        std::thread::spawn(move || {
            let t0 = std::time::Instant::now();
            while t0.elapsed() < limit {
                std::thread::sleep(Duration::from_millis(200));
                if !flag.load(Ordering::SeqCst) {
                    return;
                }
            }
            if flag.load(Ordering::SeqCst) {
                eprintln!(
                    "watchdog: chaos test '{tag}' still running after {limit:?} \
                     (seed {}) — aborting",
                    fault_seed()
                );
                std::process::exit(101);
            }
        });
        Watchdog { armed }
    }
}

impl Drop for Watchdog {
    fn drop(&mut self) {
        self.armed.store(false, Ordering::SeqCst);
    }
}

fn small_spec(seed: u64) -> JobSpec {
    let mut c = SimConfig::paper(ArchKind::Dense);
    c.window_cap = 16;
    c.batch = 1;
    c.seed = seed;
    JobSpec {
        benchmark: Benchmark::AlexNet,
        config: c,
    }
}

/// Reference bytes: what a fresh single-process simulation returns.
fn direct(spec: &JobSpec) -> String {
    run_one(&RunRequest {
        benchmark: spec.benchmark,
        config: spec.config.clone(),
    })
    .network
    .to_json()
    .to_string()
}

/// One store-backed worker node on an ephemeral port.
fn spawn_store_node(tag: &str) -> (String, std::path::PathBuf, NodeHandle) {
    let dir = scratch_dir(tag);
    let store = Arc::new(Store::open_with(&dir, false).expect("open store"));
    let cfg = SchedulerConfig {
        workers: 2,
        shards: 2,
        queue_cap: 64,
        cache_bytes: 16 << 20,
        store: Some(store),
    };
    let (addr, handle) = Server::spawn("127.0.0.1:0", cfg).expect("spawn node");
    (addr.to_string(), dir, handle)
}

fn field(j: &Json, k: &str) -> u64 {
    j.get(k)
        .and_then(Json::as_u64)
        .unwrap_or_else(|| panic!("field {k} in {j:?}"))
}

/// A 3-node cluster with an in-process router (no router TCP front end:
/// the tests script `dispatch`/`health_pass` directly for exact attempt
/// accounting) and an installed, initially-empty fault plan whose rules
/// target the stable labels `node0`/`node1`/`node2`.
struct Chaos {
    addrs: Vec<String>,
    dirs: Vec<std::path::PathBuf>,
    handles: Vec<NodeHandle>,
    router: Router,
    plan: Arc<FaultPlan>,
}

impl Chaos {
    fn boot(tag: &str, policy: TransportPolicy, steal_threshold: usize) -> Chaos {
        let nodes: Vec<_> = (0..3)
            .map(|i| spawn_store_node(&format!("{tag}-{i}")))
            .collect();
        let addrs: Vec<String> = nodes.iter().map(|(a, _, _)| a.clone()).collect();
        let mut dirs = Vec::new();
        let mut handles = Vec::new();
        for (_, d, h) in nodes {
            dirs.push(d);
            handles.push(h);
        }
        let router = Router::new(RouterConfig {
            nodes: addrs.clone(),
            steal_threshold,
            // No background health monitor: tests that need probes call
            // health_pass() themselves, so attempt counters are exact.
            health_interval: Duration::from_secs(3600),
            policy,
            ..RouterConfig::default()
        })
        .expect("router");
        let plan = Arc::new(FaultPlan::new(fault_seed()));
        for (i, a) in addrs.iter().enumerate() {
            plan.alias(a, &format!("node{i}"));
        }
        router.install_faults(plan.clone());
        Chaos {
            addrs,
            dirs,
            handles,
            router,
            plan,
        }
    }

    fn transport_counter(&self, k: &str) -> u64 {
        field(&self.router.transport().counters_json(), k)
    }

    /// Every frame must be a clean outcome: `ok:true` byte-identical to
    /// the reference, or a structured degraded error.
    fn check_frame(&self, resp: &Json, reference: &str) {
        if resp.get("ok").and_then(Json::as_bool) == Some(true) {
            assert_eq!(
                resp.get("result").expect("result field").to_string(),
                reference,
                "served result must be byte-identical: {resp:?}"
            );
        } else {
            assert_eq!(
                resp.get("degraded").and_then(Json::as_bool),
                Some(true),
                "a total failure must be a structured degraded frame: {resp:?}"
            );
            assert!(
                resp.get("error").and_then(Json::as_str).is_some(),
                "{resp:?}"
            );
        }
    }

    fn teardown(self) {
        for addr in &self.addrs {
            if let Ok(mut c) = Client::connect(addr) {
                let _ = c.shutdown();
            }
        }
        for h in self.handles {
            let _ = h.join();
        }
        for d in self.dirs {
            let _ = std::fs::remove_dir_all(d);
        }
    }
}

/// Specs split by ring ownership, mirroring the router's ring exactly
/// (same member ids, same vnode count).
fn specs_by_owner(seed_base: u64, owned_by_node0: usize, others: usize) -> Vec<JobSpec> {
    let members = [NodeId(0), NodeId(1), NodeId(2)];
    let ring = HashRing::new(&members, HashRing::DEFAULT_VNODES);
    let mut owned = Vec::new();
    let mut rest = Vec::new();
    let mut seed = seed_base;
    while owned.len() < owned_by_node0 || rest.len() < others {
        let spec = small_spec(seed);
        seed += 1;
        let owner = ring.route(&job_key(&spec.to_request())).index();
        if owner == 0 && owned.len() < owned_by_node0 {
            owned.push(spec);
        } else if owner != 0 && rest.len() < others {
            rest.push(spec);
        }
        assert!(seed < seed_base + 10_000, "ring never yielded enough keys");
    }
    // Interleave so owned keys are hit throughout the run, not first.
    let mut out = Vec::new();
    let mut o = owned.into_iter();
    let mut r = rest.into_iter();
    loop {
        match (o.next(), r.next()) {
            (None, None) => break,
            (a, b) => {
                out.extend(a);
                out.extend(b);
            }
        }
    }
    out
}

/// Dropped connections are absorbed by retries: every frame clean, and
/// every injected drop shows up as exactly one connect error.
#[test]
fn dropped_connections_retry_with_exact_accounting() {
    let _wd = Watchdog::arm("drops", Duration::from_secs(300));
    let c = Chaos::boot(
        "chaos-drop",
        TransportPolicy {
            retries: 3,
            backoff: Duration::from_millis(2),
            // Never open a breaker: this test isolates the retry path.
            breaker_threshold: 1000,
            ..TransportPolicy::default()
        },
        1 << 20,
    );
    c.plan.add_rate(FaultKind::Drop, Some("submit"), None, 0.25);
    for i in 0..10 {
        let spec = small_spec(1000 + i);
        let resp = c.router.dispatch(&spec);
        c.check_frame(&resp, &direct(&spec));
    }
    assert_eq!(
        c.transport_counter("connect_errors"),
        c.plan.injected(FaultKind::Drop),
        "every injected drop is one connect error, nothing else"
    );
    assert_eq!(c.transport_counter("timeouts"), 0);
    assert_eq!(c.transport_counter("protocol_errors"), 0);
    assert_eq!(c.transport_counter("breaker_opens"), 0);
    c.teardown();
}

/// Added latency is transparent: no retries configured, no errors
/// counted, every result still byte-identical.
#[test]
fn delays_are_transparent_and_error_free() {
    let _wd = Watchdog::arm("delays", Duration::from_secs(300));
    let c = Chaos::boot(
        "chaos-delay",
        TransportPolicy {
            retries: 0,
            ..TransportPolicy::default()
        },
        1 << 20,
    );
    c.plan.add_rate(FaultKind::Delay, Some("submit"), None, 1.0);
    for i in 0..5 {
        let spec = small_spec(2000 + i);
        let resp = c.router.dispatch(&spec);
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true), "{resp:?}");
        assert_eq!(resp.get("result").unwrap().to_string(), direct(&spec));
    }
    assert_eq!(c.plan.injected(FaultKind::Delay), 5);
    for k in ["timeouts", "connect_errors", "io_errors", "protocol_errors"] {
        assert_eq!(c.transport_counter(k), 0, "{k}");
    }
    c.teardown();
}

/// Torn response frames are protocol errors absorbed by retries (the
/// job already ran server-side, so the retry is a cache hit).
#[test]
fn truncated_frames_are_protocol_errors_absorbed_by_retries() {
    let _wd = Watchdog::arm("truncate", Duration::from_secs(300));
    let c = Chaos::boot(
        "chaos-trunc",
        TransportPolicy {
            retries: 3,
            backoff: Duration::from_millis(2),
            breaker_threshold: 1000,
            ..TransportPolicy::default()
        },
        1 << 20,
    );
    c.plan.add_rate(FaultKind::Truncate, Some("submit"), None, 0.3);
    for i in 0..10 {
        let spec = small_spec(3000 + i);
        let resp = c.router.dispatch(&spec);
        c.check_frame(&resp, &direct(&spec));
    }
    assert_eq!(
        c.transport_counter("protocol_errors"),
        c.plan.injected(FaultKind::Truncate),
        "every torn frame is one protocol error"
    );
    assert_eq!(c.transport_counter("connect_errors"), 0);
    assert_eq!(c.transport_counter("timeouts"), 0);
    c.teardown();
}

/// A black-holed node: first contact times out once, the breaker opens,
/// and every later job fails over without touching the dead node again.
#[test]
fn black_holed_node_opens_breaker_and_fails_over() {
    let _wd = Watchdog::arm("blackhole", Duration::from_secs(300));
    let c = Chaos::boot(
        "chaos-bh",
        TransportPolicy {
            retries: 0,
            breaker_threshold: 1,
            breaker_cooldown: Duration::from_secs(600),
            ..TransportPolicy::default()
        },
        1 << 20,
    );
    c.plan
        .add_rate(FaultKind::BlackHole, Some("submit"), Some("node0"), 1.0);
    let specs = specs_by_owner(4000, 4, 8);
    let owned = specs
        .iter()
        .filter(|s| {
            let members = [NodeId(0), NodeId(1), NodeId(2)];
            let ring = HashRing::new(&members, HashRing::DEFAULT_VNODES);
            ring.route(&job_key(&s.to_request())).index() == 0
        })
        .count();
    assert_eq!(owned, 4);
    for spec in &specs {
        let resp = c.router.dispatch(spec);
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true), "{resp:?}");
        assert_eq!(resp.get("result").unwrap().to_string(), direct(spec));
    }
    // One timeout total: node0 was contacted exactly once, then its
    // open breaker kept it out of every later preference order.
    assert_eq!(c.plan.injected(FaultKind::BlackHole), 1);
    assert_eq!(c.transport_counter("timeouts"), 1);
    assert_eq!(c.transport_counter("breaker_opens"), 1);
    let stats = c.router.stats_json();
    assert_eq!(field(&stats, "failovers"), owned as u64, "{stats:?}");
    assert_eq!(field(&stats, "steals"), 0);
    assert_eq!(field(&stats, "replicate_errors"), 0);
    let nodes = stats.get("nodes").and_then(Json::as_arr).unwrap();
    assert_eq!(field(&nodes[0], "served"), 0, "{nodes:?}");
    assert_eq!(nodes[0].get("alive").and_then(Json::as_bool), Some(false));
    assert_eq!(nodes[0].get("breaker").and_then(Json::as_str), Some("open"));
    c.teardown();
}

/// Total submit outage: a previously computed key is rescued stale from
/// a node's store (tagged `"source":"stale"`); an uncomputed key gets a
/// clean `degraded` error — and neither path hangs or panics.
#[test]
fn total_outage_serves_stale_then_degrades() {
    let _wd = Watchdog::arm("stale", Duration::from_secs(300));
    let c = Chaos::boot(
        "chaos-stale",
        TransportPolicy {
            retries: 0,
            breaker_threshold: 1,
            breaker_cooldown: Duration::from_secs(600),
            ..TransportPolicy::default()
        },
        1 << 20,
    );
    // Warm one key while the wire is healthy.
    let warm = small_spec(5000);
    let warm_bytes = direct(&warm);
    let resp = c.router.dispatch(&warm);
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true), "{resp:?}");
    // Now black-hole every submit, everywhere.
    c.plan.add_rate(FaultKind::BlackHole, Some("submit"), None, 1.0);
    let resp = c.router.dispatch(&warm);
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true), "{resp:?}");
    assert_eq!(
        resp.get("source").and_then(Json::as_str),
        Some("stale"),
        "a rescued result must be marked stale: {resp:?}"
    );
    assert_eq!(resp.get("result").unwrap().to_string(), warm_bytes);
    let stats = c.router.stats_json();
    assert_eq!(field(&stats, "stale_hits"), 1);
    assert_eq!(c.plan.injected(FaultKind::BlackHole), 3, "one per node");
    assert_eq!(c.transport_counter("timeouts"), 3);
    assert_eq!(c.transport_counter("breaker_opens"), 3);
    // A fresh key: every breaker is open (fast-fails, no new wire
    // contact) and no node holds a copy — the structured degraded path.
    let fresh = small_spec(5001);
    let resp = c.router.dispatch(&fresh);
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false), "{resp:?}");
    assert_eq!(resp.get("degraded").and_then(Json::as_bool), Some(true));
    let err = resp.get("error").and_then(Json::as_str).unwrap_or("");
    assert!(err.contains("no node could serve"), "{resp:?}");
    let stats = c.router.stats_json();
    assert_eq!(field(&stats, "degraded_responses"), 1);
    assert_eq!(c.transport_counter("breaker_fast_fails"), 3);
    assert_eq!(c.plan.injected(FaultKind::BlackHole), 3, "no new injections");
    c.teardown();
}

/// Duplicated request frames over the full TCP path (client → router
/// server → nodes): absorbed by content-addressed idempotency — each
/// distinct job executes exactly once cluster-wide.
#[test]
fn duplicated_requests_are_idempotent_over_the_wire() {
    let _wd = Watchdog::arm("duplicate", Duration::from_secs(300));
    let nodes: Vec<_> = (0..3)
        .map(|i| spawn_store_node(&format!("chaos-dup-{i}")))
        .collect();
    let addrs: Vec<String> = nodes.iter().map(|(a, _, _)| a.clone()).collect();
    let server = RouterServer::bind(
        "127.0.0.1:0",
        RouterConfig {
            nodes: addrs.clone(),
            steal_threshold: 1 << 20,
            health_interval: Duration::from_secs(3600),
            policy: TransportPolicy {
                retries: 0,
                breaker_threshold: 100,
                ..TransportPolicy::default()
            },
            ..RouterConfig::default()
        },
    )
    .expect("bind router");
    let raddr = server.local_addr().to_string();
    let plan = Arc::new(FaultPlan::new(fault_seed()));
    for (i, a) in addrs.iter().enumerate() {
        plan.alias(a, &format!("node{i}"));
    }
    plan.add_rate(FaultKind::Duplicate, Some("submit"), None, 1.0);
    server.router().install_faults(plan.clone());
    let rhandle = std::thread::spawn(move || server.run());

    let specs: Vec<JobSpec> = (0..8).map(|i| small_spec(6000 + i)).collect();
    let mut client = Client::connect(&raddr).expect("connect router");
    let resp = client.batch(&specs).expect("batch");
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true), "{resp:?}");
    let results = resp.get("results").and_then(Json::as_arr).unwrap();
    assert_eq!(results.len(), specs.len());
    for (spec, r) in specs.iter().zip(results) {
        assert_eq!(r.get("result").unwrap().to_string(), direct(spec));
    }
    assert_eq!(plan.injected(FaultKind::Duplicate), 8, "one per dispatch");
    let stats = client.stats().expect("stats");
    let router = stats.get("router").expect("router section");
    assert_eq!(field(router, "routed"), 8);
    // Idempotency: each distinct job executed exactly once across the
    // cluster — every duplicate resolved from the dedup/cache layers.
    let executed: u64 = addrs
        .iter()
        .map(|a| {
            let mut c = Client::connect(a).expect("connect node");
            let s = c.stats().expect("node stats");
            field(s.get("scheduler").expect("scheduler"), "executed")
        })
        .sum();
    assert_eq!(executed, 8, "duplicates must not re-execute jobs");

    let _ = client.shutdown();
    let _ = rhandle.join();
    for (addr, dir, handle) in nodes {
        if let Ok(mut c) = Client::connect(&addr) {
            let _ = c.shutdown();
        }
        let _ = handle.join();
        let _ = std::fs::remove_dir_all(dir);
    }
}

/// Regression (the old one-strike `alive` flag): a single slow health
/// probe must NOT mark a node dead — it keeps serving its keys, and
/// only `breaker_threshold` consecutive probe failures open the
/// breaker.
#[test]
fn one_slow_probe_does_not_kill_a_node() {
    let _wd = Watchdog::arm("slow-probe", Duration::from_secs(300));
    let c = Chaos::boot(
        "chaos-probe",
        TransportPolicy {
            retries: 0,
            breaker_threshold: 3,
            breaker_cooldown: Duration::from_secs(600),
            ..TransportPolicy::default()
        },
        1 << 20,
    );
    // Exactly the first health probe of node0 is black-holed.
    c.plan.force(FaultKind::BlackHole, "health", "node0", 0, 1);
    c.router.health_pass();
    assert_eq!(c.transport_counter("timeouts"), 1);
    let stats = c.router.stats_json();
    let nodes = stats.get("nodes").and_then(Json::as_arr).unwrap();
    assert_eq!(
        nodes[0].get("alive").and_then(Json::as_bool),
        Some(true),
        "one failed probe of three must not mark the node dead: {nodes:?}"
    );
    assert_eq!(nodes[0].get("breaker").and_then(Json::as_str), Some("closed"));
    // The node still receives (and serves) its own keys.
    let spec = specs_by_owner(7000, 1, 0).remove(0);
    let reference = direct(&spec);
    let resp = c.router.dispatch(&spec);
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true), "{resp:?}");
    assert_eq!(
        resp.get("node").and_then(Json::as_str),
        Some(c.addrs[0].as_str()),
        "the owner must keep serving after one slow probe: {resp:?}"
    );
    assert_eq!(resp.get("result").unwrap().to_string(), reference);
    assert_eq!(field(&c.router.stats_json(), "failovers"), 0);
    // Three *consecutive* probe failures do open it.
    c.plan.force(FaultKind::BlackHole, "health", "node0", 1, 100);
    for _ in 0..3 {
        c.router.health_pass();
    }
    assert_eq!(c.transport_counter("breaker_opens"), 1);
    let stats = c.router.stats_json();
    let nodes = stats.get("nodes").and_then(Json::as_arr).unwrap();
    assert_eq!(nodes[0].get("alive").and_then(Json::as_bool), Some(false));
    assert_eq!(nodes[0].get("breaker").and_then(Json::as_str), Some("open"));
    // Its keys now fail over — still byte-identical (successor holds
    // the replica pushed when the key was first computed).
    let resp = c.router.dispatch(&spec);
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true), "{resp:?}");
    assert_ne!(
        resp.get("node").and_then(Json::as_str),
        Some(c.addrs[0].as_str())
    );
    assert_eq!(resp.get("result").unwrap().to_string(), reference);
    c.teardown();
}

/// Overload QoS composed with wire faults: a background flood whose
/// deadlines are already expired must be shed class-exactly (the shed
/// frame is terminal at the router — never retried onto another node,
/// so client-observed sheds equal the sum of per-node counters), while
/// interleaved interactive jobs all complete with bounded latency —
/// even with ~15% of connection attempts dropped on the floor.
#[test]
fn overload_sheds_background_exactly_while_interactive_stays_bounded() {
    let _wd = Watchdog::arm("qos-overload", Duration::from_secs(300));
    let c = Chaos::boot(
        "chaos-qos",
        TransportPolicy {
            retries: 3,
            backoff: Duration::from_millis(2),
            // Never open a breaker: drops are absorbed by retries, so
            // every submission reaches exactly one node.
            breaker_threshold: 1000,
            ..TransportPolicy::default()
        },
        1 << 20,
    );
    c.plan.add_rate(FaultKind::Drop, Some("submit"), None, 0.15);

    let bg_qos = QoS {
        priority: Priority::Background,
        client: None,
        // Expired on arrival: the node must queue, then shed at pop —
        // deterministic shedding without real queue-wait races.
        deadline_ms: Some(0),
    };
    let it_qos = QoS {
        priority: Priority::Interactive,
        client: Some("dashboard".into()),
        deadline_ms: Some(30_000),
    };
    let interactive = 10u64;
    let per_round_bg = 3u64;
    let mut shed_seen = 0u64;
    let mut degraded_seen = 0u64;
    let mut interactive_ms: Vec<f64> = Vec::new();
    for i in 0..interactive {
        for k in 0..per_round_bg {
            let spec = small_spec(9000 + i * per_round_bg + k);
            let resp = c.router.dispatch_qos(&spec, &bg_qos);
            if resp.get("shed").and_then(Json::as_bool) == Some(true) {
                assert_eq!(
                    resp.get("error").and_then(Json::as_str),
                    Some("deadline_exceeded"),
                    "{resp:?}"
                );
                assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false));
                shed_seen += 1;
            } else {
                // The only other legal outcome under a Drop-only plan:
                // a fully degraded dispatch that never reached a node.
                assert_eq!(
                    resp.get("degraded").and_then(Json::as_bool),
                    Some(true),
                    "background must shed or degrade, never compute: {resp:?}"
                );
                degraded_seen += 1;
            }
        }
        let spec = small_spec(9500 + i);
        let t0 = std::time::Instant::now();
        let resp = c.router.dispatch_qos(&spec, &it_qos);
        interactive_ms.push(t0.elapsed().as_secs_f64() * 1e3);
        assert_eq!(
            resp.get("ok").and_then(Json::as_bool),
            Some(true),
            "interactive must complete under background overload: {resp:?}"
        );
        assert_eq!(resp.get("result").unwrap().to_string(), direct(&spec));
    }
    assert_eq!(shed_seen + degraded_seen, interactive * per_round_bg);
    assert!(shed_seen > 0, "the flood must actually shed");
    let p99 = percentile(&interactive_ms, 0.99);
    assert!(
        p99 < 5_000.0,
        "interactive p99 must stay bounded under overload, got {p99:.1} ms \
         (latencies {interactive_ms:?})"
    );

    // Exact accounting, three ways. Router-observed per-class counters:
    let rqos = c.router.qos_json();
    let rq = |class: &str, k: &str| {
        rqos.get(class)
            .and_then(|c| c.get(k))
            .and_then(Json::as_u64)
            .unwrap_or_else(|| panic!("router qos.{class}.{k}: {rqos:?}"))
    };
    assert_eq!(rq("background", "shed"), shed_seen);
    assert_eq!(rq("background", "routed"), 0);
    assert_eq!(rq("interactive", "routed"), interactive);
    assert_eq!(rq("interactive", "shed"), 0);
    // Node-side scheduler counters, summed across the cluster: every
    // client-observed shed is exactly one node's deadline shed.
    let node_sum = |class: &str, k: &str| -> u64 {
        c.addrs
            .iter()
            .map(|a| {
                let mut cl = Client::connect(a).expect("connect node");
                let s = cl.stats().expect("node stats");
                s.get("scheduler")
                    .and_then(|x| x.get("qos"))
                    .and_then(|q| q.get(class))
                    .and_then(|cc| cc.get(k))
                    .and_then(Json::as_u64)
                    .unwrap_or_else(|| panic!("node qos.{class}.{k}: {s:?}"))
            })
            .sum()
    };
    assert_eq!(node_sum("background", "shed_deadline"), shed_seen);
    assert_eq!(node_sum("background", "shed_overload"), 0);
    assert_eq!(node_sum("background", "admitted"), shed_seen);
    assert_eq!(node_sum("interactive", "admitted"), interactive);
    assert_eq!(node_sum("interactive", "shed_deadline"), 0);
    // And the wire-fault ledger still balances.
    assert_eq!(
        c.transport_counter("connect_errors"),
        c.plan.injected(FaultKind::Drop)
    );
    c.teardown();
}

/// The kitchen sink: a ~10% mixed fault plan (drops, black holes, torn
/// frames) over a sequential workload. Every frame is clean and the
/// per-kind accounting stays exact, whatever the seed.
#[test]
fn mixed_fault_plan_keeps_exact_accounting() {
    let _wd = Watchdog::arm("mixed", Duration::from_secs(300));
    let c = Chaos::boot(
        "chaos-mixed",
        TransportPolicy {
            retries: 2,
            backoff: Duration::from_millis(2),
            breaker_threshold: 4,
            breaker_cooldown: Duration::from_millis(100),
            ..TransportPolicy::default()
        },
        1 << 20,
    );
    c.plan.add_rate(FaultKind::Drop, Some("submit"), None, 0.10);
    c.plan.add_rate(FaultKind::BlackHole, Some("submit"), None, 0.05);
    c.plan.add_rate(FaultKind::Truncate, Some("submit"), None, 0.05);
    for i in 0..12 {
        let spec = small_spec(8000 + i);
        let resp = c.router.dispatch(&spec);
        c.check_frame(&resp, &direct(&spec));
    }
    assert_eq!(
        c.transport_counter("connect_errors"),
        c.plan.injected(FaultKind::Drop)
    );
    assert_eq!(
        c.transport_counter("timeouts"),
        c.plan.injected(FaultKind::BlackHole)
    );
    assert_eq!(
        c.transport_counter("protocol_errors"),
        c.plan.injected(FaultKind::Truncate)
    );
    c.teardown();
}
