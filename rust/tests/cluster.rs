//! Integration: the consistent-hash simulation cluster end-to-end.
//!
//! Boots real worker nodes (`Server::spawn`, each with its own tiered
//! store) behind a real `RouterServer` on ephemeral ports and checks
//! the cluster's three guarantees over actual TCP sockets:
//!
//! * **chaos / failover** — killing one worker mid-batch loses nothing:
//!   the batch completes byte-identical to direct `run_one`, and a
//!   replay of the dead node's keys is served from the successor's
//!   cold-tier replica (router stats count `failovers`/`replica_hits`);
//! * **cross-node dedup** — a warm batch replayed through a *different*
//!   node resolves entirely over the `peer-get` verb: the second node
//!   executes nothing and `report::job_accounting` reads `0 simulated`;
//! * **wire backpressure** — a cap-1 queue rejects a concurrent burst
//!   with `busy` + `retry_after_ms` frames, then drains and re-accepts.

use std::sync::Arc;
use std::time::Duration;

use barista::cluster::{HashRing, NodeId, PeerSet, Route, RouterConfig, RouterServer};
use barista::config::{ArchKind, SimConfig};
use barista::coordinator::{report, run_one, RunRequest};
use barista::service::{
    job_key, Client, JobSpec, PeerLookup, SchedulerConfig, Server, Store,
};
use barista::util::{scratch_dir, Json};
use barista::workload::Benchmark;

type NodeHandle = std::thread::JoinHandle<std::io::Result<()>>;

fn small_spec(seed: u64) -> JobSpec {
    let mut c = SimConfig::paper(ArchKind::Dense);
    c.window_cap = 16;
    c.batch = 1;
    c.seed = seed;
    JobSpec {
        benchmark: Benchmark::AlexNet,
        config: c,
    }
}

/// Reference bytes: what a fresh single-process simulation returns.
fn direct(spec: &JobSpec) -> String {
    run_one(&RunRequest {
        benchmark: spec.benchmark,
        config: spec.config.clone(),
    })
    .network
    .to_json()
    .to_string()
}

/// One store-backed worker node on an ephemeral port.
fn spawn_store_node(tag: &str) -> (String, std::path::PathBuf, NodeHandle) {
    let dir = scratch_dir(tag);
    let store = Arc::new(Store::open_with(&dir, false).expect("open store"));
    let cfg = SchedulerConfig {
        workers: 2,
        shards: 2,
        queue_cap: 64,
        cache_bytes: 16 << 20,
        store: Some(store),
    };
    let (addr, handle) = Server::spawn("127.0.0.1:0", cfg).expect("spawn node");
    (addr.to_string(), dir, handle)
}

fn shutdown(addr: &str) {
    let mut c = Client::connect(addr).expect("connect for shutdown");
    c.shutdown().expect("shutdown");
}

fn field(j: &Json, k: &str) -> u64 {
    j.get(k).and_then(Json::as_u64).unwrap_or_else(|| panic!("field {k} in {j:?}"))
}

/// Acceptance: start a 3-node cluster, run a batch, kill one worker
/// mid-batch — the batch completes with results byte-identical to a
/// single-node run, and the stats report failover replica hits.
#[test]
fn kill_one_node_mid_batch_completes_and_replays_from_replicas() {
    let nodes: Vec<_> = (0..3)
        .map(|i| spawn_store_node(&format!("cluster-chaos-{i}")))
        .collect();
    let addrs: Vec<String> = nodes.iter().map(|(a, _, _)| a.clone()).collect();
    let (raddr, rhandle) = RouterServer::spawn(
        "127.0.0.1:0",
        RouterConfig {
            nodes: addrs.clone(),
            // No steals: routing stays owner-first, so phase 1 places
            // every result on its owner and replicates to the
            // successor — the pair phase 3 depends on.
            steal_threshold: 1 << 20,
            health_interval: Duration::from_millis(50),
            ..RouterConfig::default()
        },
    )
    .expect("spawn router");
    let raddr = raddr.to_string();
    let mut client = Client::connect(&raddr).expect("connect router");

    // Phase 1 — cold batch through the router: byte-identical to
    // run_one, every fresh result replicated to a successor node.
    let specs: Vec<JobSpec> = (0..12).map(|i| small_spec(100 + i)).collect();
    let resp = client.batch(&specs).expect("cold batch");
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true), "{resp:?}");
    let results = resp.get("results").and_then(Json::as_arr).unwrap();
    assert_eq!(results.len(), specs.len());
    for (i, (spec, r)) in specs.iter().zip(results).enumerate() {
        assert_eq!(r.get("result").unwrap().to_string(), direct(spec), "cold job {i}");
    }
    let stats = client.stats().expect("router stats");
    let router = stats.get("router").expect("router section");
    assert_eq!(field(router, "replicated"), 12, "{router:?}");
    assert_eq!(field(router, "steals"), 0, "{router:?}");

    // Mirror the router's ring to pick the chaos victim: the owner of
    // specs[0], so the replay below must cross to its successor.
    let members = [NodeId(0), NodeId(1), NodeId(2)];
    let ring = HashRing::new(&members, HashRing::DEFAULT_VNODES);
    let key0 = job_key(&specs[0].to_request());
    let victim = ring.route(&key0).index();
    let victim_addr = addrs[victim].clone();

    // Phase 2 — fresh jobs in flight while the victim dies. The batch
    // must complete anyway, still byte-identical.
    let fresh: Vec<JobSpec> = (0..12).map(|i| small_spec(200 + i)).collect();
    let batch_thread = {
        let raddr = raddr.clone();
        let fresh = fresh.clone();
        std::thread::spawn(move || {
            let mut c = Client::connect(&raddr).expect("connect for chaos batch");
            c.batch(&fresh).expect("chaos batch")
        })
    };
    std::thread::sleep(Duration::from_millis(40));
    shutdown(&victim_addr); // kill one worker mid-batch
    let resp = batch_thread.join().expect("batch thread");
    assert_eq!(
        resp.get("ok").and_then(Json::as_bool),
        Some(true),
        "batch must survive losing a node: {resp:?}"
    );
    let results = resp.get("results").and_then(Json::as_arr).unwrap();
    for (i, (spec, r)) in fresh.iter().zip(results).enumerate() {
        assert_eq!(r.get("result").unwrap().to_string(), direct(spec), "chaos job {i}");
    }

    // Phase 3 — after the health monitor flags the victim dead, replay
    // phase 1: byte-identical again, with the victim's keys answered
    // from successor replicas (source "store" on a non-victim node).
    std::thread::sleep(Duration::from_millis(300));
    let resp = client.batch(&specs).expect("replay batch");
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true), "{resp:?}");
    let results = resp.get("results").and_then(Json::as_arr).unwrap();
    for (i, (spec, r)) in specs.iter().zip(results).enumerate() {
        assert_eq!(r.get("result").unwrap().to_string(), direct(spec), "replay job {i}");
    }
    let r0 = &results[0];
    assert_eq!(
        r0.get("source").and_then(Json::as_str),
        Some("store"),
        "the dead owner's key must come off a cold-tier replica: {r0:?}"
    );
    assert_ne!(
        r0.get("node").and_then(Json::as_str),
        Some(victim_addr.as_str()),
        "a dead node cannot have served the job"
    );

    let stats = client.stats().expect("router stats after chaos");
    let router = stats.get("router").expect("router section");
    assert!(field(router, "replica_hits") >= 1, "{router:?}");
    assert!(field(router, "failovers") >= 1, "{router:?}");
    assert!(field(router, "dead_marks") >= 1, "{router:?}");
    let rows = router.get("nodes").and_then(Json::as_arr).unwrap();
    let victim_row = rows
        .iter()
        .find(|n| n.get("addr").and_then(Json::as_str) == Some(victim_addr.as_str()))
        .expect("victim row in stats");
    assert_eq!(victim_row.get("alive").and_then(Json::as_bool), Some(false), "{victim_row:?}");

    // Teardown: surviving nodes, then the router.
    for (i, (addr, _, _)) in nodes.iter().enumerate() {
        if i != victim {
            shutdown(addr);
        }
    }
    shutdown(&raddr);
    rhandle.join().expect("router thread").expect("router io");
    for (_, dir, handle) in nodes {
        handle.join().expect("node thread").expect("node io");
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// Acceptance: cross-node dedup — a warm batch replayed through a
/// different node reports peer hits and `0 simulated` in
/// `report::job_accounting`.
#[test]
fn warm_batch_replayed_through_a_peer_node_simulates_nothing() {
    // Node A: store-backed, warmed directly. Node B: fresh and
    // storeless, configured with A as its dedup peer.
    let (addr_a, dir_a, handle_a) = spawn_store_node("cluster-dedup-a");
    let peers: Arc<dyn PeerLookup> = Arc::new(PeerSet::new(vec![addr_a.clone()]));
    let (addr_b, handle_b) = Server::spawn_with_peers(
        "127.0.0.1:0",
        SchedulerConfig {
            workers: 2,
            shards: 1,
            queue_cap: 64,
            cache_bytes: 16 << 20,
            store: None,
        },
        Some(peers),
    )
    .expect("spawn node B");
    let addr_b = addr_b.to_string();

    let specs: Vec<JobSpec> = (0..6).map(|i| small_spec(300 + i)).collect();
    let mut a = Client::connect(&addr_a).expect("connect A");
    let warm = a.batch(&specs).expect("warm batch on A");
    assert_eq!(warm.get("ok").and_then(Json::as_bool), Some(true), "{warm:?}");

    // Replay through B: every job resolves over the peer-get verb.
    let mut b = Client::connect(&addr_b).expect("connect B");
    let start = std::time::Instant::now();
    let replay = b.batch(&specs).expect("replay via B");
    let wall_ms = start.elapsed().as_secs_f64() * 1e3;
    let warm_results = warm.get("results").and_then(Json::as_arr).unwrap();
    let results = replay.get("results").and_then(Json::as_arr).unwrap();
    assert_eq!(results.len(), specs.len());
    for (i, (w, r)) in warm_results.iter().zip(results).enumerate() {
        assert_eq!(
            r.get("source").and_then(Json::as_str),
            Some("peer"),
            "job {i} must be a peer hit: {r:?}"
        );
        assert_eq!(
            r.get("result").unwrap().to_string(),
            w.get("result").unwrap().to_string(),
            "job {i}: peer-fetched bytes differ from the original"
        );
    }

    // B's ledger and the shared accounting line prove zero simulation.
    let stats = b.stats().expect("stats B");
    let sched = stats.get("scheduler").expect("scheduler stats");
    assert_eq!(field(sched, "executed"), 0, "{sched:?}");
    assert_eq!(field(sched, "peer_hits"), 6, "{sched:?}");
    let line = report::job_accounting(
        "cluster-replay",
        specs.len(),
        field(sched, "executed"),
        field(sched, "cache_hits"),
        field(sched, "store_hits"),
        field(sched, "peer_hits"),
        field(sched, "deduped"),
        wall_ms,
    );
    assert!(line.contains("0 simulated"), "{line}");
    assert!(line.contains("6 peer hits"), "{line}");

    // Peer hits are admitted into B's hot tier: a second replay is
    // answered locally without touching A.
    let again = b.batch(&specs).expect("second replay via B");
    for (i, r) in again.get("results").and_then(Json::as_arr).unwrap().iter().enumerate() {
        assert_eq!(
            r.get("source").and_then(Json::as_str),
            Some("cache"),
            "job {i} must now be local: {r:?}"
        );
    }

    shutdown(&addr_b);
    shutdown(&addr_a);
    handle_b.join().expect("node B thread").expect("node B io");
    handle_a.join().expect("node A thread").expect("node A io");
    let _ = std::fs::remove_dir_all(&dir_a);
}

/// Satellite: backpressure on the wire. A deliberately tiny server
/// (one worker, one shard, queue cap 1) must reject a concurrent
/// burst with `busy` + a positive `retry_after_ms`, then — once the
/// queue drains — accept the retried jobs and fresh submissions.
#[test]
fn wire_backpressure_rejects_then_drains_and_reaccepts() {
    let (addr, handle) = Server::spawn(
        "127.0.0.1:0",
        SchedulerConfig {
            workers: 1,
            shards: 1,
            queue_cap: 1,
            cache_bytes: 8 << 20,
            store: None,
        },
    )
    .expect("spawn");
    let addr = addr.to_string();

    let n = 16usize;
    let barrier = Arc::new(std::sync::Barrier::new(n));
    let mut joins = Vec::new();
    for i in 0..n {
        let addr = addr.clone();
        let barrier = barrier.clone();
        joins.push(std::thread::spawn(move || {
            let spec = small_spec(400 + i as u64);
            let want = direct(&spec);
            let mut c = Client::connect(&addr).expect("connect");
            barrier.wait();
            let mut rejections = 0u64;
            loop {
                let resp = c.submit(&spec).expect("submit");
                if resp.get("ok").and_then(Json::as_bool) == Some(true) {
                    // Drained far enough for this job — and the result
                    // is still exact.
                    assert_eq!(resp.get("result").unwrap().to_string(), want);
                    return rejections;
                }
                assert_eq!(
                    resp.get("error").and_then(Json::as_str),
                    Some("busy"),
                    "only backpressure may reject a valid job: {resp:?}"
                );
                let hint = resp
                    .get("retry_after_ms")
                    .and_then(Json::as_u64)
                    .expect("busy carries a retry hint");
                assert!(hint > 0, "{resp:?}");
                rejections += 1;
                std::thread::sleep(Duration::from_millis(hint.min(50)));
            }
        }));
    }
    let rejections: u64 = joins
        .into_iter()
        .map(|j| j.join().expect("client thread"))
        .sum();
    assert!(
        rejections >= 1,
        "16 concurrent distinct jobs against a cap-1 queue must hit busy"
    );

    // Fully drained: a fresh job is accepted without retrying, and the
    // stats ledger accounts for every rejection the clients saw.
    let mut c = Client::connect(&addr).expect("connect after burst");
    let resp = c.submit(&small_spec(999)).expect("post-drain submit");
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true), "{resp:?}");
    let stats = c.stats().expect("stats");
    let sched = stats.get("scheduler").expect("scheduler stats");
    assert_eq!(field(sched, "rejected"), rejections, "{sched:?}");
    assert_eq!(field(sched, "executed"), n as u64 + 1, "{sched:?}");

    shutdown(&addr);
    handle.join().expect("server thread").expect("server io");
}
