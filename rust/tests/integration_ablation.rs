//! Integration: the paper's ablation claims (Figure 10/11) as invariants,
//! plus property tests over the optimization toggles and failure
//! injection on degenerate workloads.

use barista::config::{ArchKind, BaristaOpts, SimConfig};
use barista::coordinator::{run_one, RunRequest};
use barista::tensor::LayerGeom;
use barista::util::prop::run_prop;
use barista::workload::{Benchmark, NetworkWork};

fn cfg_with(opts: BaristaOpts) -> SimConfig {
    let mut c = SimConfig::paper(ArchKind::BaristaNoOpts);
    c.window_cap = 256;
    c.batch = 8;
    c.opts = opts;
    c
}

fn cycles(b: Benchmark, opts: BaristaOpts) -> f64 {
    run_one(&RunRequest {
        benchmark: b,
        config: cfg_with(opts),
    })
    .network
    .cycles
}

#[test]
fn each_technique_individually_helps_or_is_neutral() {
    let b = Benchmark::AlexNet;
    let base = cycles(b, BaristaOpts::NONE);
    let with = |f: fn(&mut BaristaOpts)| {
        let mut o = BaristaOpts::NONE;
        f(&mut o);
        cycles(b, o)
    };
    let tel = with(|o| {
        o.telescoping = true;
        o.snarfing = true;
    });
    let col = with(|o| o.coloring = true);
    let rr = with(|o| o.round_robin = true);
    assert!(tel < base * 1.02, "telescoping+snarfing helps: {tel} vs {base}");
    assert!(col < base * 1.02, "coloring helps: {col} vs {base}");
    assert!(rr < base * 1.02, "round robin helps: {rr} vs {base}");
}

#[test]
fn full_stack_beats_every_single_omission() {
    let b = Benchmark::VggNet;
    let full = cycles(b, BaristaOpts::ALL_ON);
    for (name, f) in [
        ("no telescoping", (|o: &mut BaristaOpts| o.telescoping = false) as fn(&mut _)),
        ("no snarfing", |o: &mut BaristaOpts| o.snarfing = false),
        ("no coloring", |o: &mut BaristaOpts| o.coloring = false),
        ("no hierarchical", |o: &mut BaristaOpts| o.hierarchical = false),
    ] {
        let mut o = BaristaOpts::ALL_ON;
        f(&mut o);
        let c = cycles(b, o);
        assert!(
            full <= c * 1.05,
            "{name} should not beat the full stack: full {full:.0} vs {c:.0}"
        );
    }
}

#[test]
fn more_buffering_means_fewer_refetches() {
    let b = Benchmark::ResNet18;
    let mut prev = f64::INFINITY;
    for (nd, sd) in [(1usize, 8usize), (2, 12), (3, 16)] {
        let mut c = SimConfig::paper(ArchKind::Barista);
        c.window_cap = 256;
        c.batch = 8;
        c.node_buf_depth = nd;
        c.shared_buf_depth = sd;
        let r = run_one(&RunRequest {
            benchmark: b,
            config: c,
        })
        .network
        .refetch_ratio();
        assert!(
            r <= prev * 1.05,
            "refetches must not rise with more buffering: {r} after {prev}"
        );
        prev = r;
    }
}

#[test]
fn unlimited_buffer_needs_multiples_of_default() {
    let mut c = SimConfig::paper(ArchKind::UnlimitedBuffer);
    c.window_cap = 256;
    c.batch = 8;
    let r = run_one(&RunRequest {
        benchmark: Benchmark::AlexNet,
        config: c,
    });
    let default_bytes = 32768u64 * 245;
    assert!(
        r.network.peak_buffer_bytes > default_bytes,
        "unlimited buffering observes straying beyond the default budget"
    );
}

// ---- failure injection / degenerate workloads --------------------------

fn degenerate_layer(density_f: f64, density_m: f64) -> NetworkWork {
    let mut cfg = SimConfig::paper(ArchKind::Barista);
    cfg.window_cap = 64;
    cfg.batch = 1;
    let spec = barista::workload::networks::NetworkSpec {
        benchmark: Benchmark::AlexNet,
        layers: vec![LayerGeom {
            h: 8,
            w: 8,
            d: 128,
            k: 3,
            n: 96,
            stride: 1,
            pad: 1,
        }],
        filter_density: density_f,
        map_density: density_m,
        per_layer: None,
    };
    NetworkWork::from_spec(spec, &cfg)
}

#[test]
fn all_zero_feature_maps_do_not_hang() {
    // ReLU killed everything: zero matched work everywhere.
    let work = degenerate_layer(0.5, 0.0);
    for arch in [ArchKind::Barista, ArchKind::SparTen, ArchKind::Ideal] {
        let mut cfg = SimConfig::paper(arch);
        cfg.window_cap = 64;
        cfg.batch = 1;
        let r = barista::coordinator::run_with_work(&cfg, &work);
        assert!(r.network.cycles.is_finite());
        assert!(r.network.cycles >= 0.0);
    }
}

#[test]
fn fully_dense_masks_match_dense_work() {
    // Density 1.0: two-sided matched == dense MAC count.
    let work = degenerate_layer(1.0, 1.0);
    let l = &work.layers[0];
    // Density clamps (0.98 cap) and per-row jitter pull the effective
    // density below 1; matched fraction ≈ df_eff × dm_eff ≈ 0.9² — it
    // must still be far above any sparse regime.
    let frac = l.matched_macs_sampled() as f64
        / (l.windows.rows * l.filters.rows * l.geom.vec_len()) as f64;
    assert!(frac > 0.75, "matched fraction at density 1: {frac}");
}

#[test]
fn prop_opts_monotonicity_random_densities() {
    run_prop("opts never hurt", 0xAB1A7E, 8, |rng| {
        let df = 0.15 + 0.7 * rng.next_f64();
        let dm = 0.15 + 0.7 * rng.next_f64();
        let work = degenerate_layer(df, dm);
        let mut cfg_full = SimConfig::paper(ArchKind::Barista);
        cfg_full.window_cap = 64;
        cfg_full.batch = 1;
        let full = barista::coordinator::run_with_work(&cfg_full, &work)
            .network
            .cycles;
        let mut cfg_none = SimConfig::paper(ArchKind::BaristaNoOpts);
        cfg_none.window_cap = 64;
        cfg_none.batch = 1;
        let none = barista::coordinator::run_with_work(&cfg_none, &work)
            .network
            .cycles;
        if full > none * 1.1 {
            return Err(format!(
                "opts hurt at df={df:.2} dm={dm:.2}: {full:.0} vs {none:.0}"
            ));
        }
        Ok(())
    });
}
