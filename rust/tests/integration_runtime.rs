//! Integration: the PJRT runtime path (AOT artifacts → Rust execution),
//! cross-checked against the native Rust reference.
//!
//! These tests need `make artifacts` to have run; they skip (with a
//! visible message) when the artifacts are absent so `cargo test` works
//! in a fresh checkout, while `make test` always exercises them. The
//! PJRT-executing tests additionally need the `pjrt` feature (vendored
//! `xla` crate); the native-model tests always run.

use barista::runtime;
use barista::util::rng::Pcg32;

#[cfg(feature = "pjrt")]
use barista::runtime::ArtifactStore;

#[cfg(feature = "pjrt")]
fn artifacts_dir() -> Option<&'static str> {
    if std::path::Path::new("artifacts/chunk_gemm.hlo.txt").exists() {
        Some("artifacts")
    } else {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts` first");
        None
    }
}

#[cfg(feature = "pjrt")]
#[test]
fn golden_check_passes() {
    let Some(dir) = artifacts_dir() else { return };
    runtime::golden_check(dir).expect("golden check");
}

#[cfg(feature = "pjrt")]
#[test]
fn artifact_store_lists_and_caches() {
    let Some(dir) = artifacts_dir() else { return };
    let store = ArtifactStore::open(dir).expect("open");
    let names = store.available();
    assert!(names.contains(&"chunk_gemm".to_string()), "{names:?}");
    assert!(names.contains(&"smallcnn".to_string()), "{names:?}");
    // Loading twice returns the cached executable (same Arc).
    let a = store.load("chunk_gemm").unwrap();
    let b = store.load("chunk_gemm").unwrap();
    assert!(std::sync::Arc::ptr_eq(&a, &b));
}

#[cfg(feature = "pjrt")]
#[test]
fn chunk_gemm_respects_masks() {
    // Masking out everything must zero the product even with non-zero
    // values — the bitmask semantics end-to-end through XLA.
    let Some(dir) = artifacts_dir() else { return };
    let store = ArtifactStore::open(dir).expect("open");
    let exe = store.load("chunk_gemm").unwrap();
    let (m, k, n) = runtime::CHUNK_GEMM_SHAPE;
    let a = vec![1.0f32; m * k];
    let am = vec![0.0f32; m * k];
    let b = vec![1.0f32; k * n];
    let bm = vec![1.0f32; k * n];
    let out = exe
        .run_f32(&[
            (&a, &[m as i64, k as i64]),
            (&am, &[m as i64, k as i64]),
            (&b, &[k as i64, n as i64]),
            (&bm, &[k as i64, n as i64]),
        ])
        .unwrap();
    assert!(out.iter().all(|&x| x == 0.0), "masked-out product must be 0");
}

#[cfg(feature = "pjrt")]
#[test]
fn smallcnn_relu_and_shape() {
    let Some(dir) = artifacts_dir() else { return };
    let store = ArtifactStore::open(dir).expect("open");
    let exe = store.load("smallcnn").unwrap();
    let cnn = runtime::smallcnn_golden(7, 0.5);
    let bsz = runtime::SMALLCNN_BATCH;
    let hw = runtime::SMALLCNN_HW as i64;
    let mut rng = Pcg32::seeded(3);
    let x: Vec<f32> = (0..bsz * (hw * hw) as usize * runtime::SMALLCNN_C[0])
        .map(|_| rng.next_f64() as f32 - 0.5)
        .collect();
    let mut inputs: Vec<(&[f32], Vec<i64>)> = vec![(&x, vec![bsz as i64, hw, hw, 8])];
    for l in &cnn.layers {
        inputs.push((&l.weights, vec![3, 3, l.geom.d as i64, l.geom.n as i64]));
        inputs.push((&l.bias, vec![l.geom.n as i64]));
    }
    let refs: Vec<(&[f32], &[i64])> = inputs.iter().map(|(d, s)| (*d, s.as_slice())).collect();
    let out = exe.run_f32(&refs).unwrap();
    assert_eq!(
        out.len(),
        bsz * (hw * hw) as usize * runtime::SMALLCNN_C[3]
    );
    assert!(out.iter().all(|&v| v >= 0.0), "final ReLU output");
    // And it matches the native Rust forward exactly (fp tolerance).
    let (want, _) = cnn.forward(&x, bsz);
    assert!(runtime::max_abs_diff(&out, &want) < 1e-2);
}

#[test]
fn golden_cnn_density_measurement_sane() {
    // No artifacts needed: the native model alone.
    let cnn = runtime::smallcnn_golden(11, 0.4);
    let mut rng = Pcg32::seeded(4);
    let x: Vec<f32> = (0..runtime::SMALLCNN_BATCH * 16 * 16 * 8)
        .map(|_| rng.next_f64() as f32 - 0.5)
        .collect();
    let (_, obs) = cnn.forward(&x, runtime::SMALLCNN_BATCH);
    assert_eq!(obs.len(), 3);
    for o in &obs {
        assert!((0.3..0.6).contains(&o.filter_density), "{o:?}");
        assert!((0.1..0.9).contains(&o.output_density), "{o:?}");
    }
}
