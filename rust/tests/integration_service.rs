//! Integration: the job service end-to-end over a real TCP socket.
//!
//! Boots `Server` on an ephemeral port, fires concurrent clients with
//! overlapping job sets, and checks the service's three guarantees:
//! every submission gets a response, responses are byte-identical to a
//! direct `run_one`, and identical jobs are simulated exactly once
//! (dedup + cache, visible in the stats counters).

use std::collections::HashMap;
use std::sync::Arc;

use barista::config::{ArchKind, SimConfig};
use barista::coordinator::{run_one, RunRequest};
use barista::service::{job_key, Client, JobSpec, Scheduler, SchedulerConfig, Server, Source};
use barista::util::{Json, Pcg32};
use barista::workload::{Benchmark, SparsityModel};

fn small_cfg(arch: ArchKind, seed: u64) -> SimConfig {
    let mut c = SimConfig::paper(arch);
    c.window_cap = 16;
    c.batch = 1;
    c.seed = seed;
    c
}

fn small_spec(benchmark: Benchmark, arch: ArchKind, seed: u64) -> JobSpec {
    JobSpec {
        benchmark,
        config: small_cfg(arch, seed),
    }
}

fn test_server() -> (std::net::SocketAddr, std::thread::JoinHandle<std::io::Result<()>>) {
    Server::spawn(
        "127.0.0.1:0",
        SchedulerConfig {
            workers: 4,
            shards: 2,
            queue_cap: 128,
            cache_bytes: 32 << 20,
            store: None,
        },
    )
    .expect("bind ephemeral port")
}

#[test]
fn concurrent_clients_dedup_and_match_run_one() {
    let (addr, server) = test_server();
    let addr_s = addr.to_string();

    // 4 distinct jobs shared by 8 clients × 3 submissions = 24
    // submissions with heavy overlap.
    let pool: Vec<JobSpec> = vec![
        small_spec(Benchmark::AlexNet, ArchKind::Dense, 1),
        small_spec(Benchmark::AlexNet, ArchKind::Ideal, 1),
        small_spec(Benchmark::ResNet18, ArchKind::Dense, 1),
        small_spec(Benchmark::AlexNet, ArchKind::Dense, 2),
    ];
    let pool = Arc::new(pool);

    let mut joins = Vec::new();
    for client_id in 0..8usize {
        let pool = pool.clone();
        let addr_s = addr_s.clone();
        joins.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr_s).expect("connect");
            let mut got: Vec<(usize, String)> = Vec::new();
            for k in 0..3usize {
                let idx = (client_id + k) % pool.len();
                let resp = client.submit(&pool[idx]).expect("submit");
                assert_eq!(
                    resp.get("ok").and_then(Json::as_bool),
                    Some(true),
                    "client {client_id} job {idx}: {resp:?}"
                );
                let result = resp.get("result").expect("result present");
                got.push((idx, result.to_string()));
            }
            got
        }));
    }
    let mut responses: Vec<(usize, String)> = Vec::new();
    for j in joins {
        responses.extend(j.join().expect("client thread"));
    }
    assert_eq!(responses.len(), 24, "all responses arrived");

    // (b) byte-identical to a direct run_one of the same job.
    let mut direct: HashMap<usize, String> = HashMap::new();
    for (i, spec) in pool.iter().enumerate() {
        let r = run_one(&RunRequest {
            benchmark: spec.benchmark,
            config: spec.config.clone(),
        });
        direct.insert(i, r.network.to_json().to_string());
    }
    for (idx, body) in &responses {
        assert_eq!(
            body, &direct[idx],
            "service result for job {idx} differs from direct run_one"
        );
    }

    // (c) stats prove deduplication: 4 distinct jobs, 24 submissions.
    let mut client = Client::connect(&addr_s).expect("connect for stats");
    let stats = client.stats().expect("stats");
    let sched = stats.get("scheduler").expect("scheduler stats");
    let executed = sched.get("executed").and_then(Json::as_u64).unwrap();
    let deduped = sched.get("deduped").and_then(Json::as_u64).unwrap();
    let cache_hits = sched.get("cache_hits").and_then(Json::as_u64).unwrap();
    let submitted = sched.get("submitted").and_then(Json::as_u64).unwrap();
    assert_eq!(executed, 4, "each distinct job simulated exactly once");
    assert_eq!(submitted, 24);
    assert_eq!(deduped + cache_hits, 20, "the other 20 submissions reused");

    let resp = client.shutdown().expect("shutdown");
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true));
    server.join().expect("server thread").expect("server io");
}

#[test]
fn batch_roundtrip_preserves_order_and_sources() {
    let (addr, server) = test_server();
    let mut client = Client::connect(&addr.to_string()).expect("connect");

    let specs = vec![
        small_spec(Benchmark::AlexNet, ArchKind::Dense, 3),
        small_spec(Benchmark::AlexNet, ArchKind::Ideal, 3),
        small_spec(Benchmark::AlexNet, ArchKind::Dense, 3), // duplicate of [0]
    ];
    let resp = client.batch(&specs).expect("batch");
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true), "{resp:?}");
    let results = resp.get("results").and_then(Json::as_arr).unwrap();
    assert_eq!(results.len(), 3);
    // Order preserved: entries 0 and 2 are the same job, entry 1 differs.
    let body = |i: usize| results[i].get("result").unwrap().to_string();
    assert_eq!(body(0), body(2));
    assert_ne!(body(0), body(1));
    let arch = |i: usize| {
        results[i]
            .get("result")
            .and_then(|r| r.get("arch"))
            .and_then(Json::as_str)
            .unwrap()
            .to_string()
    };
    assert_eq!(arch(0), "dense");
    assert_eq!(arch(1), "ideal");

    // A second identical batch is served entirely from cache.
    let resp2 = client.batch(&specs).expect("batch 2");
    let results2 = resp2.get("results").and_then(Json::as_arr).unwrap();
    for (i, r) in results2.iter().enumerate() {
        assert_eq!(
            r.get("source").and_then(Json::as_str),
            Some("cache"),
            "second-batch job {i} must be a cache hit"
        );
    }

    client.shutdown().expect("shutdown");
    server.join().expect("server thread").expect("server io");
}

#[test]
fn protocol_errors_do_not_kill_the_connection() {
    let (addr, server) = test_server();
    let mut client = Client::connect(&addr.to_string()).expect("connect");

    // Garbage, unknown op, unknown config key: each gets an error
    // response and the connection stays usable.
    let r = client.roundtrip(&Json::Str("not an object".into())).unwrap();
    assert_eq!(r.get("ok").and_then(Json::as_bool), Some(false));

    let bad_op = Json::parse(r#"{"op":"frobnicate"}"#).unwrap();
    let r = client.roundtrip(&bad_op).unwrap();
    assert_eq!(r.get("ok").and_then(Json::as_bool), Some(false));

    let typo = Json::parse(
        r#"{"op":"submit","job":{"network":"alexnet","config":{"windowcap":64}}}"#,
    )
    .unwrap();
    let r = client.roundtrip(&typo).unwrap();
    assert_eq!(r.get("ok").and_then(Json::as_bool), Some(false));
    assert!(
        r.get("error").and_then(Json::as_str).unwrap().contains("windowcap"),
        "typo'd key must be named: {r:?}"
    );

    // Still alive: a valid submit succeeds.
    let ok = client
        .submit(&small_spec(Benchmark::AlexNet, ArchKind::Ideal, 4))
        .unwrap();
    assert_eq!(ok.get("ok").and_then(Json::as_bool), Some(true));

    let status = client.status().unwrap();
    assert_eq!(status.get("ok").and_then(Json::as_bool), Some(true));
    assert!(status.get("uptime_ms").and_then(Json::as_u64).is_some());

    client.shutdown().expect("shutdown");
    server.join().expect("server thread").expect("server io");
}

/// Differential test of `barista serve`'s scheduler: a randomized job
/// mix — including jobs that differ *only* in their sparsity model —
/// must (a) hash to pairwise-distinct cache keys, (b) produce results
/// byte-identical to a fresh `run_one` of the same job, and (c) serve
/// a replay of the whole mix entirely from cache, byte-identical again.
/// Guards the scenario extension of the content-addressed cache key.
#[test]
fn randomized_job_mix_is_cache_exact_across_sparsity_models() {
    let sched = Scheduler::new(SchedulerConfig {
        workers: 4,
        shards: 2,
        queue_cap: 128,
        cache_bytes: 64 << 20,
        store: None,
    });
    // Deterministic "random" pool: benchmarks × archs × scenarios,
    // with one group differing only in the sparsity model.
    let mut pool: Vec<RunRequest> = Vec::new();
    for (i, model) in SparsityModel::ALL.iter().enumerate() {
        let arch = if i % 2 == 0 {
            ArchKind::Barista
        } else {
            ArchKind::Dense
        };
        let mut c = small_cfg(arch, 11);
        c.sparsity = *model;
        pool.push(RunRequest {
            benchmark: Benchmark::AlexNet,
            config: c,
        });
        // The sparsity-only variant group: identical everything, only
        // the model differs.
        let mut c2 = small_cfg(ArchKind::Ideal, 12);
        c2.sparsity = *model;
        pool.push(RunRequest {
            benchmark: Benchmark::ResNet18,
            config: c2,
        });
    }
    // (a) all keys pairwise distinct.
    for i in 0..pool.len() {
        for j in (i + 1)..pool.len() {
            assert_ne!(
                job_key(&pool[i]),
                job_key(&pool[j]),
                "jobs {i} and {j} collide on the cache key"
            );
        }
    }

    let mut rng = Pcg32::seeded(0xD1FF);
    let mix: Vec<RunRequest> = (0..32)
        .map(|_| pool[rng.gen_range(pool.len() as u32) as usize].clone())
        .collect();
    let first = sched.run_all(&mix).expect("first mix");
    // (b) byte-identical to fresh simulations.
    let mut fresh: HashMap<String, String> = HashMap::new();
    for req in &pool {
        fresh.insert(
            job_key(req).hex(),
            run_one(req).network.to_json().to_string(),
        );
    }
    for (o, req) in first.iter().zip(&mix) {
        assert_eq!(
            o.entry.network_json,
            fresh[&job_key(req).hex()],
            "scheduler result differs from fresh run_one for {} {} {}",
            req.benchmark,
            req.config.arch,
            req.config.sparsity
        );
    }
    // (c) replay: all cache hits, byte-identical to the first pass.
    let replay = sched.run_all(&mix).expect("replay mix");
    for (i, (a, b)) in first.iter().zip(&replay).enumerate() {
        assert_eq!(b.source, Source::CacheHit, "replay job {i} not a cache hit");
        assert_eq!(a.entry.network_json, b.entry.network_json, "replay job {i}");
    }
    let distinct = mix
        .iter()
        .map(|r| job_key(r).hex())
        .collect::<std::collections::BTreeSet<_>>()
        .len();
    let stats = sched.stats();
    assert_eq!(
        stats.executed as usize, distinct,
        "each distinct job simulated exactly once: {stats:?}"
    );
}

#[test]
fn in_process_scheduler_reuses_sweep_results_across_figures() {
    // The `barista report --figure all` path without the CLI: the same
    // sweep submitted twice against one scheduler simulates only once.
    let sched = Scheduler::new(SchedulerConfig {
        workers: 4,
        shards: 2,
        queue_cap: 64,
        cache_bytes: 32 << 20,
        store: None,
    });
    let base = small_cfg(ArchKind::Barista, 5);
    let reqs = barista::coordinator::sweep_requests(
        &[Benchmark::AlexNet],
        &[ArchKind::Dense, ArchKind::Barista, ArchKind::Ideal],
        &base,
    );
    let first = sched.run_results(&reqs).expect("first sweep");
    let s1 = sched.stats();
    assert_eq!(s1.executed, 3);
    let second = sched.run_results(&reqs).expect("second sweep");
    let s2 = sched.stats();
    assert_eq!(s2.executed, 3, "second figure does zero simulation work");
    assert_eq!(s2.cache_hits, s1.cache_hits + 3);
    for (a, b) in first.iter().zip(&second) {
        assert_eq!(
            a.network.to_json().to_string(),
            b.network.to_json().to_string()
        );
    }
}

#[test]
fn streaming_submit_acks_before_the_result() {
    let (addr, server) = test_server();
    let mut client = Client::connect(&addr.to_string()).expect("connect");

    let spec = small_spec(Benchmark::AlexNet, ArchKind::Barista, 31);
    let mut events: Vec<Json> = Vec::new();
    let final_frame = client
        .submit_stream(&spec, |ev| events.push(ev.clone()))
        .expect("stream submit");

    // Exactly one non-terminal frame: the accepted ack, carrying the
    // job's 128-bit content address.
    assert_eq!(events.len(), 1, "{events:?}");
    assert_eq!(
        events[0].get("event").and_then(Json::as_str),
        Some("accepted")
    );
    let key = events[0].get("key").and_then(Json::as_str).unwrap();
    assert_eq!(key.len(), 32, "hex 128-bit key: {key}");

    // The terminal frame is the result, byte-identical to run_one.
    assert_eq!(final_frame.get("ok").and_then(Json::as_bool), Some(true));
    assert_eq!(
        final_frame.get("event").and_then(Json::as_str),
        Some("result")
    );
    let direct = run_one(&RunRequest {
        benchmark: spec.benchmark,
        config: spec.config.clone(),
    });
    assert_eq!(
        final_frame.get("result").unwrap().to_string(),
        direct.network.to_json().to_string(),
        "streamed result must be byte-identical to run_one"
    );

    // The connection still speaks the one-line protocol afterwards.
    let status = client.status().expect("status after stream");
    assert_eq!(status.get("ok").and_then(Json::as_bool), Some(true));

    client.shutdown().expect("shutdown");
    server.join().expect("server thread").expect("server io");
}

#[test]
fn streaming_batch_reports_each_job_then_a_done_summary() {
    let (addr, server) = test_server();
    let mut client = Client::connect(&addr.to_string()).expect("connect");

    let specs = vec![
        small_spec(Benchmark::AlexNet, ArchKind::Dense, 33),
        small_spec(Benchmark::AlexNet, ArchKind::Ideal, 33),
        small_spec(Benchmark::AlexNet, ArchKind::Dense, 33), // dup of [0]
    ];
    let mut events: Vec<Json> = Vec::new();
    let done = client
        .batch_stream(&specs, |ev| events.push(ev.clone()))
        .expect("stream batch");

    // Frame order: accepted first, then one progress per job.
    assert!(!events.is_empty());
    assert_eq!(
        events[0].get("event").and_then(Json::as_str),
        Some("accepted")
    );
    assert_eq!(events[0].get("jobs").and_then(Json::as_u64), Some(3));
    let progress: Vec<&Json> = events[1..].iter().collect();
    assert_eq!(progress.len(), 3, "{events:?}");
    let mut indexes: Vec<usize> = progress
        .iter()
        .map(|e| e.get("index").and_then(Json::as_usize).unwrap())
        .collect();
    indexes.sort_unstable();
    assert_eq!(indexes, vec![0, 1, 2], "each job reported exactly once");

    // Every progress body matches the non-streaming response for the
    // same job (byte-identical result payloads).
    let direct: Vec<String> = specs
        .iter()
        .map(|s| {
            run_one(&RunRequest {
                benchmark: s.benchmark,
                config: s.config.clone(),
            })
            .network
            .to_json()
            .to_string()
        })
        .collect();
    for ev in &progress {
        let idx = ev.get("index").and_then(Json::as_usize).unwrap();
        assert_eq!(
            ev.get("result").unwrap().to_string(),
            direct[idx],
            "progress frame for job {idx}"
        );
    }

    // The done summary counts this batch's sources exactly: two
    // distinct jobs, one reuse (dedup or cache depending on timing).
    assert_eq!(done.get("ok").and_then(Json::as_bool), Some(true));
    assert_eq!(done.get("event").and_then(Json::as_str), Some("done"));
    assert_eq!(done.get("jobs").and_then(Json::as_u64), Some(3));
    let field = |k: &str| done.get(k).and_then(Json::as_u64).unwrap();
    assert_eq!(field("executed"), 2, "{done:?}");
    assert_eq!(field("cache") + field("dedup"), 1, "{done:?}");
    assert_eq!(field("store"), 0, "{done:?}");

    // A streamed replay is served without re-execution.
    let mut replay_events: Vec<Json> = Vec::new();
    let done2 = client
        .batch_stream(&specs, |ev| replay_events.push(ev.clone()))
        .expect("stream replay");
    assert_eq!(done2.get("executed").and_then(Json::as_u64), Some(0));
    assert_eq!(done2.get("cache").and_then(Json::as_u64), Some(3));

    client.shutdown().expect("shutdown");
    server.join().expect("server thread").expect("server io");
}
