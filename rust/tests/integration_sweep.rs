//! Integration: whole-stack simulation sweeps — the paper's comparative
//! claims as executable invariants.

use barista::config::{ArchKind, SimConfig};
use barista::coordinator::{run_one, Coordinator, RunRequest, RunResult};
use barista::workload::Benchmark;

fn cfg(arch: ArchKind) -> SimConfig {
    let mut c = SimConfig::paper(arch);
    c.window_cap = 384;
    c.batch = 16;
    c
}

fn run(b: Benchmark, arch: ArchKind) -> RunResult {
    run_one(&RunRequest {
        benchmark: b,
        config: cfg(arch),
    })
}

#[test]
fn figure7_ordering_holds_on_alexnet() {
    let b = Benchmark::AlexNet;
    let dense = run(b, ArchKind::Dense).network.cycles;
    let sparten = run(b, ArchKind::SparTen).network.cycles;
    let sync = run(b, ArchKind::Synchronous).network.cycles;
    let barista = run(b, ArchKind::Barista).network.cycles;
    let ideal = run(b, ArchKind::Ideal).network.cycles;

    assert!(barista < sparten, "BARISTA beats SparTen");
    assert!(barista < sync, "BARISTA beats Synchronous");
    assert!(barista < dense / 3.0, "BARISTA >3x over Dense on AlexNet");
    assert!(ideal <= barista, "nothing beats Ideal");
    assert!(
        barista < ideal * 2.0,
        "BARISTA within 2x of ideal: {barista:.0} vs {ideal:.0}"
    );
}

#[test]
fn two_sided_beats_one_sided_beats_dense_on_vgg() {
    let b = Benchmark::VggNet;
    let dense = run(b, ArchKind::Dense).network.cycles;
    let one = run(b, ArchKind::OneSided).network.cycles;
    let sparten = run(b, ArchKind::SparTen).network.cycles;
    assert!(one < dense, "one-sided beats dense on VGG");
    assert!(sparten < one, "two-sided beats one-sided on VGG");
}

#[test]
fn iso_area_sparten_is_slower_than_full() {
    let b = Benchmark::AlexNet;
    let full = run(b, ArchKind::SparTen).network.cycles;
    let iso = run(b, ArchKind::SparTenIso).network.cycles;
    assert!(iso > full, "fewer MACs at iso-area must cost time");
}

#[test]
fn barista_no_opts_slower_than_barista() {
    let b = Benchmark::ResNet18;
    let full = run(b, ArchKind::Barista).network.cycles;
    let none = run(b, ArchKind::BaristaNoOpts).network.cycles;
    assert!(
        none > full * 1.2,
        "the optimizations must matter: {none:.0} vs {full:.0}"
    );
}

#[test]
fn breakdown_components_cover_total_time() {
    for arch in [
        ArchKind::Dense,
        ArchKind::OneSided,
        ArchKind::SparTen,
        ArchKind::Synchronous,
        ArchKind::Barista,
    ] {
        let r = run(Benchmark::AlexNet, arch);
        let total_pe_cycles = r.network.cycles * cfg(arch).total_macs() as f64;
        let sum = r.network.breakdown.total();
        let rel = (sum - total_pe_cycles).abs() / total_pe_cycles;
        assert!(
            rel < 0.35,
            "{arch}: breakdown {sum:.3e} vs cycles*pes {total_pe_cycles:.3e} (rel {rel:.3})"
        );
    }
}

#[test]
fn energy_counters_consistent_across_two_sided_archs() {
    // All two-sided architectures perform the same effectual MACs.
    let b = Benchmark::AlexNet;
    let sp = run(b, ArchKind::SparTen).network.energy.matched_macs as f64;
    let ba = run(b, ArchKind::Barista).network.energy.matched_macs as f64;
    let rel = (sp - ba).abs() / ba;
    assert!(rel < 0.05, "matched MACs disagree: sparten {sp} vs barista {ba}");
}

#[test]
fn coordinator_parallel_sweep_is_deterministic() {
    let reqs: Vec<RunRequest> = [ArchKind::Barista, ArchKind::SparTen, ArchKind::Dense]
        .iter()
        .map(|&a| RunRequest {
            benchmark: Benchmark::AlexNet,
            config: cfg(a),
        })
        .collect();
    let one = Coordinator::with_workers(1).run_all(reqs.clone());
    let many = Coordinator::with_workers(8).run_all(reqs);
    for (a, b) in one.iter().zip(&many) {
        assert_eq!(a.network.cycles, b.network.cycles);
        assert_eq!(a.network.traffic.refetch_lines, b.network.traffic.refetch_lines);
    }
}

#[test]
fn refetch_ratio_barista_far_below_no_opts() {
    let b = Benchmark::AlexNet;
    let full = run(b, ArchKind::Barista).network.refetch_ratio();
    let none = run(b, ArchKind::BaristaNoOpts).network.refetch_ratio();
    assert!(
        full < none / 5.0,
        "combining+snarfing must slash refetches: {full:.2} vs {none:.2}"
    );
}

#[test]
fn dense_insensitive_to_sparsity_sparse_archs_not() {
    // Dense time is the same regardless of density; BARISTA's is not.
    let r18 = run(Benchmark::ResNet18, ArchKind::Dense);
    let ba18 = run(Benchmark::ResNet18, ArchKind::Barista);
    // Per-MAC-normalized times:
    let d_norm = r18.network.cycles / r18.network.breakdown.total();
    assert!(d_norm.is_finite());
    let speedup = r18.network.cycles / ba18.network.cycles;
    assert!(
        speedup > 3.0,
        "ResNet18 (high sparsity) should show >3x: {speedup:.2}"
    );
}
