//! Property-based cross-architecture invariant suite (ISSUE 3).
//!
//! Built on `util::prop::run_prop`: every property runs over random
//! configurations *and* random sparsity scenarios, and a failure panics
//! with the exact `(seed, case)` pair that reproduces it. The seed and
//! case count are environment-tunable so CI pins a fixed seed while
//! `make prop` runs a deeper sweep:
//!
//! * `PROP_SEED`  — base seed (default `0xBA7157A`, what CI uses);
//! * `PROP_CASES` — multiplier on the per-property case counts
//!   (default 1; `make prop` uses 8).
//!
//! Invariants held:
//! 1. Ideal cycles lower-bound every architecture's cycles;
//! 2. two-sided matched MACs ≤ one-sided MACs ≤ dense MACs;
//! 3. the shared pass-table path equals direct mask arithmetic
//!    (`matched_macs_sampled_cached == matched_macs_sampled`);
//! 4. `gb_s_order` is a permutation and even/odd GB-S assignments are
//!    mutually reversed;
//! 5. every sparsity model tracks its target density;
//! 6. the tiled-SoA table build (serial and pool-parallel) is
//!    bit-identical to the scalar reference build;
//! 7. the cluster's consistent-hash ring splits the key space within
//!    ±20% of uniform for 2..=16 nodes, and removing a node remaps
//!    only that node's keys — each to its old successor.

use barista::arch::PassTable;
use barista::config::{ArchKind, SimConfig};
use barista::coordinator::{run_one, sweep_requests, RunRequest};
use barista::tensor::LayerGeom;
use barista::util::prop::run_prop;
use barista::util::rng::Pcg32;
use barista::workload::{alternating_assignment, gb_s_order, Benchmark, NetworkWork, SparsityModel};

/// Read a tuning env var; a set-but-unparseable value is a hard error,
/// never a silent fall-back — a typo'd `PROP_SEED` must not "pass" by
/// quietly running the default seed.
fn env_u64(name: &str, default: u64) -> u64 {
    match std::env::var(name) {
        Err(_) => default,
        Ok(s) => s
            .parse()
            .unwrap_or_else(|e| panic!("{name}='{s}' must be a decimal integer: {e}")),
    }
}

fn prop_seed() -> u64 {
    env_u64("PROP_SEED", 0xBA7157A)
}

fn cases(base: u64) -> u64 {
    base * env_u64("PROP_CASES", 1).max(1)
}

/// A random scenario, parameters included.
fn random_model(rng: &mut Pcg32) -> SparsityModel {
    match rng.gen_range(5) {
        0 => SparsityModel::Bernoulli,
        1 => SparsityModel::Clustered {
            run: 2 + rng.gen_range(62),
        },
        2 => SparsityModel::ChannelSkew {
            hot_pct: 5 + rng.gen_range(60),
        },
        3 => SparsityModel::BankBalanced {
            bank: 8 << rng.gen_range(5), // 8..=128
        },
        _ => SparsityModel::LayerDecay {
            decay_pct: 10 + rng.gen_range(85),
        },
    }
}

/// A random small conv layer.
fn random_geom(rng: &mut Pcg32) -> LayerGeom {
    let k = if rng.gen_bool(0.5) { 1 } else { 3 };
    LayerGeom {
        h: 4 + rng.gen_range(12) as usize,
        w: 4 + rng.gen_range(12) as usize,
        d: 16 + rng.gen_range(240) as usize,
        k,
        n: 8 + rng.gen_range(120) as usize,
        stride: 1,
        pad: k / 2,
    }
}

fn small_cfg(rng: &mut Pcg32, arch: ArchKind) -> SimConfig {
    let mut cfg = SimConfig::paper(arch);
    cfg.window_cap = 8 + rng.gen_range(32) as usize;
    cfg.batch = 1;
    cfg.seed = rng.next_u64();
    cfg.sparsity = random_model(rng);
    cfg
}

/// One random layer workload under a random scenario.
fn random_layer(rng: &mut Pcg32) -> barista::workload::LayerWork {
    let geom = random_geom(rng);
    let cfg = small_cfg(rng, ArchKind::Barista);
    let fd = 0.1 + 0.7 * rng.next_f64();
    let md = 0.1 + 0.7 * rng.next_f64();
    NetworkWork::layer(0, &geom, fd, md, &cfg)
}

/// Invariant 1: the Ideal configuration (unlimited bandwidth/buffering,
/// perfect spread) lower-bounds every other architecture at equal total
/// MACs and shared workload knobs — on every benchmark, seed, and
/// scenario. The per-arch configs come from `sweep_requests`, the same
/// helper the coordinator and service use, so the workload-knob set can
/// never silently diverge from the memo key.
#[test]
fn prop_ideal_lower_bounds_every_architecture() {
    const ARCHS: [ArchKind; 9] = [
        ArchKind::Dense,
        ArchKind::OneSided,
        ArchKind::Scnn,
        ArchKind::SparTen,
        ArchKind::SparTenIso,
        ArchKind::Synchronous,
        ArchKind::BaristaNoOpts,
        ArchKind::Barista,
        ArchKind::UnlimitedBuffer,
    ];
    run_prop("ideal lower-bounds all archs", prop_seed(), cases(4), |rng| {
        let benchmark = if rng.gen_bool(0.5) {
            Benchmark::AlexNet
        } else {
            Benchmark::ResNet18
        };
        let base = small_cfg(rng, ArchKind::Ideal);
        let ideal = run_one(&RunRequest {
            benchmark,
            config: base.clone(),
        })
        .network
        .cycles;
        for req in sweep_requests(&[benchmark], &ARCHS, &base) {
            let got = run_one(&req).network.cycles;
            if ideal > got * (1.0 + 1e-9) {
                return Err(format!(
                    "{benchmark} {} ({}): ideal {ideal:.3e} > {} {got:.3e}",
                    base.sparsity,
                    base.seed,
                    req.config.arch
                ));
            }
        }
        Ok(())
    });
}

/// Invariant 2: per sampled layer, two-sided matched work ≤ one-sided
/// work ≤ dense work, whatever the scenario shapes the masks into.
#[test]
fn prop_matched_leq_one_sided_leq_dense() {
    run_prop("matched<=one-sided<=dense", prop_seed(), cases(48), |rng| {
        let l = random_layer(rng);
        let matched = l.matched_macs_sampled();
        let one_sided = l.one_sided_macs_sampled();
        let dense =
            l.windows.rows as u64 * l.geom.vec_len() as u64 * l.filters.rows as u64;
        if matched > one_sided {
            return Err(format!("matched {matched} > one-sided {one_sided}"));
        }
        if one_sided > dense {
            return Err(format!("one-sided {one_sided} > dense {dense}"));
        }
        Ok(())
    });
}

/// Invariant 3: the shared pass-table fast path and the direct
/// mask-arithmetic path agree exactly on matched-MAC accounting.
#[test]
fn prop_pass_table_equals_direct_path() {
    run_prop("table path == direct path", prop_seed(), cases(24), |rng| {
        let l = random_layer(rng);
        let cached = l.matched_macs_sampled_cached();
        let direct = l.matched_macs_sampled();
        if cached != direct {
            return Err(format!("table {cached} != direct {direct}"));
        }
        Ok(())
    });
}

/// Invariant 6: the tiled-SoA table build — serial or fanned across
/// the layer pool — is bit-identical to the scalar reference build on
/// random layers (all costs, all rotations, every supported `parts`).
#[test]
fn prop_tiled_soa_build_matches_scalar() {
    run_prop("tiled SoA build == scalar build", prop_seed(), cases(12), |rng| {
        let l = random_layer(rng);
        let parts = [1usize, 2, 4, 8][rng.gen_range(4) as usize];
        let scalar = PassTable::build_scalar(&l.filters, &l.windows, parts);
        let tiled = PassTable::build_serial(&l.filters, &l.windows, parts);
        let parallel = PassTable::build_parallel(&l.filters, &l.windows, parts);
        let (Some(scalar), Some(tiled), Some(parallel)) = (scalar, tiled, parallel) else {
            return Err(format!("parts={parts}: geometry failed to tabulate"));
        };
        let rot = rng.gen_range(parts as u32) as usize;
        let oh = rng.gen_range(3) as u64;
        for f in 0..l.filters.rows {
            for w in 0..l.windows.rows {
                let want = scalar.cost(f, w, rot, oh);
                if tiled.cost(f, w, rot, oh) != want {
                    return Err(format!("serial != scalar at parts={parts} f={f} w={w}"));
                }
                if parallel.cost(f, w, rot, oh) != want {
                    return Err(format!("parallel != scalar at parts={parts} f={f} w={w}"));
                }
            }
        }
        if tiled.total_matched() != scalar.total_matched()
            || parallel.total_matched() != scalar.total_matched()
        {
            return Err(format!("parts={parts}: total_matched diverged"));
        }
        Ok(())
    });
}

/// Invariant 4: GB-S density ordering is a permutation of the filters,
/// and the even/odd-map assignments walk it in mutually reverse order.
#[test]
fn prop_gb_s_permutation_and_alternation() {
    run_prop("gb-s permutation + alternation", prop_seed(), cases(48), |rng| {
        let rows = 4 + rng.gen_range(124) as usize;
        let model = random_model(rng);
        let vec_len = 128 + rng.gen_range(1024) as usize;
        let filters = model.filter_masks(rng, rows, vec_len, 0.2 + 0.6 * rng.next_f64());
        let order = gb_s_order(&filters);
        let mut sorted = order.clone();
        sorted.sort_unstable();
        if sorted != (0..rows).collect::<Vec<_>>() {
            return Err(format!("{model}: gb_s_order is not a permutation"));
        }
        for w in order.windows(2) {
            if filters.row_nnz(w[0]) < filters.row_nnz(w[1]) {
                return Err(format!("{model}: order not descending by density"));
            }
        }
        let positions = 1 + rng.gen_range(64) as usize;
        let rounds = (rows + positions - 1) / positions;
        let round = rng.gen_range(rounds as u32) as usize;
        let map = 2 * rng.gen_range(16) as usize;
        let even = alternating_assignment(&order, positions, round, map, true);
        let odd = alternating_assignment(&order, positions, round, map + 1, true);
        let mut rev = even.clone();
        rev.reverse();
        if odd != rev {
            return Err(format!(
                "{model}: odd map is not the reverse of the even map"
            ));
        }
        Ok(())
    });
}

/// Invariant 5: every scenario hits its requested density (network
/// matrices are large enough that sampling noise is small).
#[test]
fn prop_scenarios_track_target_density() {
    run_prop("scenario density tracking", prop_seed(), cases(24), |rng| {
        let model = random_model(rng);
        let density = 0.15 + 0.6 * rng.next_f64();
        // Multiple of 128 cells so truncation doesn't shave the target.
        let vec_len = 128 * (2 + rng.gen_range(8) as usize);
        let f = model.filter_masks(rng, 192, vec_len, density);
        let w = model.window_masks(rng, 192, vec_len, density);
        for (label, m) in [("filters", &f), ("windows", &w)] {
            let got = m.density();
            // Tolerance sized ≥4σ for the worst case (long clustered
            // runs shrink the effective sample; bank rounding biases up
            // to 0.5/bank) so the fixed-seed CI run can't flake.
            if (got - density).abs() > 0.12 {
                return Err(format!(
                    "{model} {label}: density {got:.3} vs target {density:.3}"
                ));
            }
        }
        Ok(())
    });
}

/// Invariant 7: the cluster router's consistent-hash ring (a) splits
/// the 2^64 key space near-uniformly — every member's analytic arc
/// share stays within ±20% of 1/n across 2..=16 nodes — and (b) is
/// minimally disruptive: removing one node leaves every other node's
/// keys where they were, and each orphaned key lands exactly on its
/// old successor (the replica holder, which is what makes cold-tier
/// replication a usable failover path).
#[test]
fn prop_hash_ring_balance_and_minimal_remap() {
    use barista::cluster::{HashRing, NodeId, Route};
    use barista::service::JobKey;
    run_prop("ring balance + minimal remap", prop_seed(), cases(12), |rng| {
        let n = 2 + rng.gen_range(15) as usize; // 2..=16 nodes
        let members: Vec<NodeId> = (0..n as u32).map(NodeId).collect();
        let ring = HashRing::new(&members, HashRing::DEFAULT_VNODES);
        // (a) balance, measured analytically from arc lengths — no
        // sampling noise in the acceptance band.
        let ideal = 1.0 / n as f64;
        for (node, share) in ring.shares() {
            if (share - ideal).abs() > 0.2 * ideal {
                return Err(format!(
                    "n={n} {node:?}: share {share:.4} vs ideal {ideal:.4} (±20%)"
                ));
            }
        }
        // (b) minimal remap over random 128-bit job keys.
        let victim = members[rng.gen_range(n as u32) as usize];
        let mut shrunk = ring.clone();
        shrunk.remove(victim);
        for _ in 0..256 {
            let key = JobKey(rng.next_u64(), rng.next_u64());
            let before = ring.route(&key);
            let after = shrunk.route(&key);
            if before != victim && after != before {
                return Err(format!(
                    "n={n}: a key owned by surviving {before:?} moved to {after:?}"
                ));
            }
            if before == victim {
                let successor = ring.preference(&key, 2).get(1).copied();
                if Some(after) != successor {
                    return Err(format!(
                        "n={n}: orphaned key went to {after:?}, not its successor {successor:?}"
                    ));
                }
            }
        }
        Ok(())
    });
}

/// Invariant 5b: layer-decay's depth profile is monotone non-increasing
/// and roughly mean-preserving for realistic targets.
#[test]
fn prop_layer_decay_monotone() {
    run_prop("layer-decay monotone", prop_seed(), cases(48), |rng| {
        let decay_pct = 10 + rng.gen_range(90);
        let model = SparsityModel::LayerDecay { decay_pct };
        let layers = 2 + rng.gen_range(46) as usize;
        let fd = 0.2 + 0.4 * rng.next_f64();
        let md = 0.2 + 0.4 * rng.next_f64();
        let mut prev = (f64::MAX, f64::MAX);
        let mut sum = 0.0;
        for i in 0..layers {
            let (a, b) = model.depth_profile(fd, md, i, layers);
            if a > prev.0 + 1e-12 || b > prev.1 + 1e-12 {
                return Err(format!("layer {i}: profile increased"));
            }
            prev = (a, b);
            sum += a;
        }
        let mean = sum / layers as f64;
        // Clamping at 0.98 can shave up to ~0.097 off the mean for the
        // steepest short-network cases; 0.12 bounds it with margin.
        if (mean - fd).abs() > 0.12 {
            return Err(format!("mean {mean:.3} drifted from target {fd:.3}"));
        }
        Ok(())
    });
}
