//! §Perf optimization-equivalence suite (DESIGN.md §Perf).
//!
//! The PR that introduced the shared pass tables, the workload memo,
//! the zero-allocation cluster scratch and the layer-parallel reduce
//! promised *bit-identical* results. These tests hold it (and every
//! future perf PR) to that: the optimized `run_one` must reproduce the
//! pre-optimization reference path exactly — per-layer cycles,
//! breakdown, traffic, energy — for every architecture, and a pinned
//! golden value catches silent drift across releases.

use barista::arch::{kernel, pass_pe_cycles, PassTable};
use barista::config::{ArchKind, SimConfig};
use barista::coordinator::{run_one, run_one_reference, ExecOptions, RunRequest};
use barista::workload::{Benchmark, NetworkWork, SparsityModel};

fn req(arch: ArchKind, window_cap: usize, batch: usize) -> RunRequest {
    let mut c = SimConfig::paper(arch);
    c.window_cap = window_cap;
    c.batch = batch;
    RunRequest {
        benchmark: Benchmark::AlexNet,
        config: c,
    }
}

/// The table-backed, memoized, layer-parallel path must be bit-identical
/// to the old direct path for every architecture the repo models.
#[test]
fn optimized_bit_identical_to_reference_across_archs() {
    for arch in ArchKind::ALL {
        let r = req(arch, 48, 2);
        let fast = run_one(&r);
        let slow = run_one_reference(&r);
        assert_eq!(
            fast.network.layers.len(),
            slow.network.layers.len(),
            "{arch}: layer count"
        );
        for (i, (a, b)) in fast
            .network
            .layers
            .iter()
            .zip(&slow.network.layers)
            .enumerate()
        {
            assert_eq!(
                a.cycles.to_bits(),
                b.cycles.to_bits(),
                "{arch} layer {i}: cycles {} vs {}",
                a.cycles,
                b.cycles
            );
            assert_eq!(a.breakdown, b.breakdown, "{arch} layer {i}: breakdown");
            assert_eq!(a.traffic, b.traffic, "{arch} layer {i}: traffic");
            assert_eq!(a.energy, b.energy, "{arch} layer {i}: energy");
            assert_eq!(
                a.peak_buffer_bytes, b.peak_buffer_bytes,
                "{arch} layer {i}: peak buffer"
            );
            assert_eq!(
                a.refetch_ratio.to_bits(),
                b.refetch_ratio.to_bits(),
                "{arch} layer {i}: refetch ratio"
            );
        }
        assert_eq!(
            fast.network.to_json().to_string(),
            slow.network.to_json().to_string(),
            "{arch}: serialized network result"
        );
    }
}

/// Every combination of the two independent optimizations must agree —
/// layer parallelism and the table path are separately toggleable.
#[test]
fn all_exec_option_combinations_agree() {
    let r = req(ArchKind::Barista, 32, 1);
    let base = run_one_reference(&r).network.to_json().to_string();
    for layer_parallel in [false, true] {
        for reference in [false, true] {
            let got = barista::coordinator::run_one_with(
                &r,
                ExecOptions {
                    layer_parallel,
                    reference,
                },
            );
            assert_eq!(
                got.network.to_json().to_string(),
                base,
                "layer_parallel={layer_parallel} reference={reference}"
            );
        }
    }
}

/// Pinned-golden cycles for one fixed seed: catches *silent* semantic
/// drift that an equivalence test (which re-derives both sides) cannot.
/// The golden file self-seals on the first run in a fresh environment;
/// once committed, any cycle change must be deliberate — bump
/// `SIM_VERSION` and refresh this file together.
#[test]
fn pinned_golden_barista_alexnet_cycles() {
    let r = req(ArchKind::Barista, 64, 2);
    let got = run_one(&r).network.cycles;
    assert!(got.is_finite() && got > 0.0, "sane cycles: {got}");
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/golden");
    let path = concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/tests/golden/barista_alexnet_cap64_batch2_cycles.txt"
    );
    match std::fs::read_to_string(path) {
        Ok(s) => {
            let want: f64 = s.trim().parse().unwrap_or_else(|e| {
                panic!("golden file {path} must hold one f64: {e}")
            });
            assert_eq!(
                got.to_bits(),
                want.to_bits(),
                "pinned BARISTA AlexNet cycles drifted: got {got}, golden {want}. \
                 If this change is intentional, bump SIM_VERSION in src/lib.rs \
                 (the service cache key) and refresh {path}."
            );
        }
        Err(_) => {
            // First run in this environment: seal the measured value.
            std::fs::create_dir_all(dir).expect("create golden dir");
            std::fs::write(path, format!("{got}\n")).expect("seal golden file");
            println!("sealed golden: {got} -> {path}");
        }
    }
}

/// The tiled-SoA table build (PR 4) — auto, serial, and forced pool-
/// parallel — must equal the scalar AoS reference build *and* the
/// direct per-pass arithmetic, bit for bit, for every supported
/// partition count × rotation × sparsity scenario on a real workload
/// layer. This is the kernel-level contract the end-to-end equivalence
/// tests above inherit.
#[test]
fn tiled_soa_build_bit_identical_across_scenarios() {
    for model in SparsityModel::ALL {
        let mut cfg = SimConfig::paper(ArchKind::Barista);
        cfg.window_cap = 24;
        cfg.batch = 1;
        cfg.sparsity = model;
        let net = NetworkWork::generate(Benchmark::AlexNet, &cfg);
        let layer = &net.layers[1];
        for parts in [1usize, 2, 4, 8] {
            let scalar = PassTable::build_scalar(&layer.filters, &layer.windows, parts)
                .expect("paper geometry tabulates");
            let auto = PassTable::build(&layer.filters, &layer.windows, parts).unwrap();
            let serial = PassTable::build_serial(&layer.filters, &layer.windows, parts).unwrap();
            let parallel =
                PassTable::build_parallel(&layer.filters, &layer.windows, parts).unwrap();
            for f in 0..layer.filters.rows {
                for w in 0..layer.windows.rows {
                    for rot in 0..parts {
                        let want = pass_pe_cycles(
                            layer.filters.row(f),
                            layer.windows.row(w),
                            parts,
                            rot,
                            2,
                        );
                        assert_eq!(
                            scalar.cost(f, w, rot, 2),
                            want,
                            "{model} parts={parts} scalar f={f} w={w} rot={rot}"
                        );
                        assert_eq!(
                            auto.cost(f, w, rot, 2),
                            want,
                            "{model} parts={parts} auto f={f} w={w} rot={rot}"
                        );
                        assert_eq!(
                            serial.cost(f, w, rot, 2),
                            want,
                            "{model} parts={parts} serial f={f} w={w} rot={rot}"
                        );
                        assert_eq!(
                            parallel.cost(f, w, rot, 2),
                            want,
                            "{model} parts={parts} parallel f={f} w={w} rot={rot}"
                        );
                    }
                }
            }
            assert_eq!(scalar.total_matched(), parallel.total_matched());
            assert_eq!(scalar.total_matched(), layer.matched_macs_sampled());
            // The explicit kernel matrix (PR 8): SWAR × prescan ×
            // SIMD-when-available, serial and pool-parallel, all held
            // to the scalar reference by a full-table compare — on
            // this same real workload layer, per sparsity model.
            for (kname, kern) in kernel::all_available() {
                let ks =
                    PassTable::build_kernel_serial(&layer.filters, &layer.windows, parts, kern)
                        .unwrap_or_else(|| panic!("{model} {kname} serial parts={parts}"));
                scalar.assert_bit_identical(&ks);
                let kp =
                    PassTable::build_kernel_parallel(&layer.filters, &layer.windows, parts, kern)
                        .unwrap_or_else(|| panic!("{model} {kname} parallel parts={parts}"));
                scalar.assert_bit_identical(&kp);
            }
        }
    }
}

/// `BARISTA_KERNEL=scalar` end to end: a whole optimized run under the
/// forced scalar table-build path must still serialize byte-identically
/// to the reference run. (Sets the process env; the concurrent tests in
/// this binary may transiently build tables via the scalar kernel,
/// which is harmless — every kernel is bit-identical, as proved above.)
#[test]
fn forced_scalar_env_override_end_to_end() {
    let prev = std::env::var(kernel::KERNEL_ENV).ok();
    std::env::set_var(kernel::KERNEL_ENV, "scalar");
    assert_eq!(kernel::active_kernel_label(), "scalar");
    let r = req(ArchKind::Barista, 32, 1);
    let fast = run_one(&r).network.to_json().to_string();
    let slow = run_one_reference(&r).network.to_json().to_string();
    assert_eq!(fast, slow, "forced-scalar run diverged from reference");
    match prev {
        Some(v) => std::env::set_var(kernel::KERNEL_ENV, v),
        None => std::env::remove_var(kernel::KERNEL_ENV),
    }
}

/// Determinism under the shared layer pool: repeated optimized runs are
/// byte-identical (regression guard for scheduling-dependent state).
#[test]
fn optimized_runs_are_deterministic() {
    let r = req(ArchKind::Barista, 48, 2);
    let a = run_one(&r).network.to_json().to_string();
    for _ in 0..3 {
        assert_eq!(run_one(&r).network.to_json().to_string(), a);
    }
}
