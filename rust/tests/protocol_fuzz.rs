//! Protocol robustness fuzz: seeded junk against live servers.
//!
//! A malformed request line — torn frame, invalid UTF-8, oversized
//! line, wrong-shape JSON, bad cluster verbs — must always produce a
//! structured `{"ok":false,"error":...}` response on the same
//! connection, never a panic, a hang, or a dropped connection (a
//! disconnect would let one buggy client trigger a reconnect storm).
//!
//! Runs in tier-1 (`cargo test`). Seeded like `tests/invariants.rs`:
//! `PROP_SEED` picks the generator stream, `PROP_CASES` scales volume;
//! CI logs the nightly seed for replay.
//!
//! Also pins the stats/health wire schemas (worker scheduler, router,
//! peer set, transport counters): `barista stats --json` consumers get
//! additive evolution only — a renamed or dropped key fails here.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use barista::cluster::{PeerSet, Router, RouterConfig, RouterServer};
use barista::config::{ArchKind, SimConfig};
use barista::coordinator::{run_one, RunRequest};
use barista::service::{
    Client, JobSpec, PeerLookup, QoS, Request, Scheduler, SchedulerConfig, Server,
};
use barista::util::prop::run_prop;
use barista::util::rng::Pcg32;
use barista::util::Json;
use barista::workload::Benchmark;

fn env_u64(name: &str, default: u64) -> u64 {
    match std::env::var(name) {
        Err(_) => default,
        Ok(s) => s
            .parse()
            .unwrap_or_else(|e| panic!("{name}='{s}' must be a u64: {e}")),
    }
}

fn prop_seed() -> u64 {
    env_u64("PROP_SEED", 0xBA7157A)
}

fn cases(base: u64) -> u64 {
    base * env_u64("PROP_CASES", 1).max(1)
}

fn small_cfg() -> SchedulerConfig {
    SchedulerConfig {
        workers: 1,
        shards: 1,
        queue_cap: 16,
        cache_bytes: 1 << 20,
        store: None,
    }
}

fn small_spec(seed: u64) -> JobSpec {
    let mut c = SimConfig::paper(ArchKind::Dense);
    c.window_cap = 16;
    c.batch = 1;
    c.seed = seed;
    JobSpec {
        benchmark: Benchmark::AlexNet,
        config: c,
    }
}

/// A raw byte-level protocol connection: no client-side framing help,
/// so tests can send exactly the bytes they mean to.
struct RawConn {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl RawConn {
    fn open(addr: &str) -> RawConn {
        let stream = TcpStream::connect(addr).expect("connect");
        stream.set_nodelay(true).ok();
        // A missing response is a test failure, not a hang.
        stream
            .set_read_timeout(Some(Duration::from_secs(10)))
            .ok();
        let reader = BufReader::new(stream.try_clone().expect("clone"));
        RawConn {
            reader,
            writer: stream,
        }
    }

    /// Send one line (the newline is appended) and read one response.
    fn roundtrip(&mut self, line: &[u8]) -> Result<Json, String> {
        self.writer.write_all(line).map_err(|e| format!("send: {e}"))?;
        self.writer.write_all(b"\n").map_err(|e| format!("send: {e}"))?;
        self.writer.flush().map_err(|e| format!("flush: {e}"))?;
        let mut buf = String::new();
        let n = self
            .reader
            .read_line(&mut buf)
            .map_err(|e| format!("recv: {e}"))?;
        if n == 0 {
            return Err("server closed the connection".into());
        }
        Json::parse(buf.trim_end()).map_err(|e| format!("unparseable response: {e}"))
    }
}

/// One seeded junk request line. Never empty/whitespace-only (those are
/// legitimately ignored without a response) and never containing a
/// newline (that would be two frames).
fn junk_line(rng: &mut Pcg32, valid_submit: &str) -> Vec<u8> {
    match rng.gen_range(6) {
        // Raw bytes, mostly invalid UTF-8.
        0 => {
            let mut v = vec![b'x'];
            for _ in 0..1 + rng.gen_range(63) {
                let b = rng.gen_range(256) as u8;
                if b != b'\n' && b != b'\r' {
                    v.push(b);
                }
            }
            v
        }
        // Printable non-JSON junk.
        1 => {
            let words = ["hello", "GET / HTTP/1.1", "{unclosed", "]]]]", "op=submit"];
            words[rng.gen_range(words.len() as u32) as usize]
                .as_bytes()
                .to_vec()
        }
        // Parseable JSON of the wrong shape.
        2 => {
            let shapes = [
                r#"{"op":12}"#,
                r#"[]"#,
                r#"42"#,
                r#""submit""#,
                r#"{"no_op":1}"#,
                r#"{"op":"frobnicate"}"#,
                r#"{"op":"submit"}"#,
                r#"{"op":"batch","jobs":[]}"#,
                r#"{"op":"submit","job":{"network":"nope"}}"#,
                r#"{"op":"submit","job":{"network":"alexnet","windowcap":9}}"#,
            ];
            shapes[rng.gen_range(shapes.len() as u32) as usize]
                .as_bytes()
                .to_vec()
        }
        // A torn (strict-prefix) copy of a perfectly valid submit.
        3 => {
            let cut = 1 + rng.gen_range(valid_submit.len() as u32 - 2) as usize;
            valid_submit.as_bytes()[..cut].to_vec()
        }
        // Bad cluster verbs.
        4 => {
            let shapes = [
                r#"{"op":"peer-get"}"#,
                r#"{"op":"replicate","key":"xyz","payload":"p"}"#,
                r#"{"op":"replicate","key":"ab"}"#,
                r#"{"op":"replicate","key":"00000000000000000000000000000000","payload":"not a record"}"#,
            ];
            shapes[rng.gen_range(shapes.len() as u32) as usize]
                .as_bytes()
                .to_vec()
        }
        // A job that is not even an object.
        _ => br#"{"op":"submit","job":[]}"#.to_vec(),
    }
}

/// Every junk frame gets one structured error on the same connection,
/// and the connection still answers a real request afterwards.
#[test]
fn seeded_junk_never_kills_a_worker_connection() {
    let (addr, handle) = Server::spawn("127.0.0.1:0", small_cfg()).expect("spawn server");
    let addr = addr.to_string();
    let valid_submit = Request::Submit {
        spec: small_spec(1),
        stream: false,
        qos: QoS::default(),
    }
    .to_json()
    .to_string();
    let mut conn = RawConn::open(&addr);
    run_prop("protocol-junk", prop_seed(), cases(60), |rng| {
        let junk = junk_line(rng, &valid_submit);
        let resp = conn.roundtrip(&junk)?;
        if resp.get("ok").and_then(Json::as_bool) != Some(false) {
            return Err(format!("junk {junk:?} answered ok: {resp:?}"));
        }
        let err = resp.get("error").and_then(Json::as_str).unwrap_or("");
        if err.is_empty() {
            return Err(format!("junk {junk:?}: error message missing: {resp:?}"));
        }
        // The same connection must still serve real traffic.
        let health = conn.roundtrip(br#"{"op":"health"}"#)?;
        if health.get("ok").and_then(Json::as_bool) != Some(true) {
            return Err(format!("health after junk failed: {health:?}"));
        }
        Ok(())
    });
    let mut c = Client::connect(&addr).expect("connect");
    c.shutdown().expect("shutdown");
    let _ = handle.join();
}

/// A client that dies mid-frame (torn write, no newline) must not take
/// the server with it.
#[test]
fn torn_frame_then_disconnect_leaves_server_healthy() {
    let (addr, handle) = Server::spawn("127.0.0.1:0", small_cfg()).expect("spawn server");
    let addr = addr.to_string();
    let valid_submit = Request::Submit {
        spec: small_spec(2),
        stream: false,
        qos: QoS::default(),
    }
    .to_json()
    .to_string();
    {
        let mut torn = RawConn::open(&addr);
        torn.writer
            .write_all(&valid_submit.as_bytes()[..valid_submit.len() / 2])
            .expect("torn write");
        torn.writer.flush().expect("flush");
        // Drop both halves: the server sees EOF mid-line.
    }
    let mut c = Client::connect(&addr).expect("connect after torn frame");
    let health = c.roundtrip(&{
        let mut j = Json::obj();
        j.set("op", "health");
        j
    });
    let health = health.expect("health");
    assert_eq!(health.get("ok").and_then(Json::as_bool), Some(true), "{health:?}");
    let stats = c.stats().expect("stats");
    assert_eq!(stats.get("ok").and_then(Json::as_bool), Some(true), "{stats:?}");
    c.shutdown().expect("shutdown");
    let _ = handle.join();
}

/// An oversized request line is drained and answered with a structured
/// error — bounded memory, connection intact.
#[test]
fn oversized_line_is_rejected_not_fatal() {
    let (addr, handle) = Server::spawn("127.0.0.1:0", small_cfg()).expect("spawn server");
    let addr = addr.to_string();
    let mut conn = RawConn::open(&addr);
    let big = vec![b'a'; (1 << 20) + 100];
    let resp = conn.roundtrip(&big).expect("oversized roundtrip");
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false), "{resp:?}");
    let err = resp.get("error").and_then(Json::as_str).unwrap_or("");
    assert!(err.contains("too long"), "{resp:?}");
    // Same connection, real request.
    let health = conn.roundtrip(br#"{"op":"health"}"#).expect("health");
    assert_eq!(health.get("ok").and_then(Json::as_bool), Some(true), "{health:?}");
    let mut c = Client::connect(&addr).expect("connect");
    c.shutdown().expect("shutdown");
    let _ = handle.join();
}

/// The router front end survives the same abuse — and then still routes
/// a real job, byte-identical (exercising the transport in tier-1).
#[test]
fn router_survives_junk_and_still_routes() {
    let (naddr, nhandle) = Server::spawn("127.0.0.1:0", small_cfg()).expect("spawn node");
    let naddr = naddr.to_string();
    let (raddr, rhandle) = RouterServer::spawn(
        "127.0.0.1:0",
        RouterConfig {
            nodes: vec![naddr.clone()],
            health_interval: Duration::from_secs(3600),
            ..RouterConfig::default()
        },
    )
    .expect("spawn router");
    let raddr = raddr.to_string();
    let mut conn = RawConn::open(&raddr);
    // Invalid UTF-8 junk.
    let resp = conn.roundtrip(&[b'x', 0xff, 0xfe, b'{']).expect("junk");
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false), "{resp:?}");
    // A worker-only verb: structured error, not a hang.
    let resp = conn
        .roundtrip(br#"{"op":"peer-get","job":{"network":"alexnet"}}"#)
        .expect("peer-get");
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false), "{resp:?}");
    assert!(
        resp.get("error")
            .and_then(Json::as_str)
            .unwrap_or("")
            .contains("no results"),
        "{resp:?}"
    );
    // Junk JSON.
    let resp = conn.roundtrip(br#"{"op":[1,2]}"#).expect("junk json");
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false), "{resp:?}");
    // Still healthy, still a router.
    let health = conn.roundtrip(br#"{"op":"health"}"#).expect("health");
    assert_eq!(health.get("ok").and_then(Json::as_bool), Some(true), "{health:?}");
    assert_eq!(health.get("role").and_then(Json::as_str), Some("router"));
    // And a real job still routes end-to-end, byte-identical.
    let spec = small_spec(3);
    let reference = run_one(&RunRequest {
        benchmark: spec.benchmark,
        config: spec.config.clone(),
    })
    .network
    .to_json()
    .to_string();
    let mut c = Client::connect(&raddr).expect("connect router");
    let resp = c.submit(&spec).expect("submit");
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true), "{resp:?}");
    assert_eq!(resp.get("result").unwrap().to_string(), reference);
    c.shutdown().expect("shutdown router");
    let _ = rhandle.join();
    let mut c = Client::connect(&naddr).expect("connect node");
    c.shutdown().expect("shutdown node");
    let _ = nhandle.join();
}

fn keys(j: &Json) -> Vec<String> {
    j.as_obj()
        .unwrap_or_else(|| panic!("expected object: {j:?}"))
        .keys()
        .cloned()
        .collect()
}

/// Schema pins: the resilience counters `barista stats --json` exposes.
/// Additive evolution only — extend the expected lists when adding
/// keys; never rename or drop without a deliberate break here.
#[test]
fn stats_wire_schemas_are_pinned() {
    // Router stats body.
    let router = Router::new(RouterConfig {
        nodes: vec!["127.0.0.1:9".into()],
        ..RouterConfig::default()
    })
    .expect("router");
    let stats = router.stats_json();
    assert_eq!(
        keys(&stats),
        [
            "dead_marks",
            "degraded_responses",
            "failovers",
            "nodes",
            "qos",
            "replica_hits",
            "replicate_errors",
            "replicated",
            "routed",
            "stale_hits",
            "steals",
            "transport",
        ]
    );
    // Per-class router QoS block.
    let rqos = stats.get("qos").unwrap();
    assert_eq!(keys(rqos), ["background", "batch", "interactive"]);
    assert_eq!(
        keys(rqos.get("interactive").unwrap()),
        ["quota_rejected", "routed", "shed"]
    );
    // Transport counter block (also under PeerSet stats).
    assert_eq!(
        keys(stats.get("transport").unwrap()),
        [
            "attempts",
            "breaker_fast_fails",
            "breaker_opens",
            "connect_errors",
            "io_errors",
            "protocol_errors",
            "retries",
            "timeouts",
        ]
    );
    // Per-node row.
    let node = &stats.get("nodes").and_then(Json::as_arr).unwrap()[0];
    assert_eq!(
        keys(node),
        ["addr", "alive", "breaker", "inflight", "queued", "served"]
    );
    // Peer-lookup stats (the worker's health/stats "peers" section).
    let peers = PeerSet::new(vec!["127.0.0.1:9".into()]);
    let pstats = peers.stats_json().expect("peer stats");
    assert_eq!(
        keys(&pstats),
        ["breakers_open", "errors", "hits", "misses", "peers", "transport"]
    );
    // Worker health + scheduler stats bodies (in-process respond).
    let scheduler = Scheduler::new(small_cfg());
    let started = Instant::now();
    let (health, _) = barista::service::server::respond(r#"{"op":"health"}"#, &scheduler, started);
    assert_eq!(keys(&health), ["ok", "op", "qos", "queued", "workers"]);
    let sched_json = scheduler.stats().to_json();
    assert_eq!(
        keys(&sched_json),
        [
            "cache",
            "cache_hits",
            "deduped",
            "executed",
            "peer_hits",
            "qos",
            "queued",
            "rejected",
            "shards",
            "store_hits",
            "submitted",
            "workers",
        ]
    );
    // Per-class scheduler QoS block: one object per class, fixed fields.
    let sqos = sched_json.get("qos").unwrap();
    assert_eq!(keys(sqos), ["background", "batch", "interactive"]);
    assert_eq!(
        keys(sqos.get("batch").unwrap()),
        [
            "admitted",
            "quota_rejected",
            "shed_deadline",
            "shed_overload",
            "starved_window",
        ]
    );
    scheduler.shutdown();
    // A peer-wired scheduler surfaces the peers section in health.
    let peers: Arc<dyn PeerLookup> = Arc::new(PeerSet::new(vec!["127.0.0.1:9".into()]));
    let scheduler = Scheduler::with_peers(small_cfg(), Some(peers));
    let (health, _) = barista::service::server::respond(r#"{"op":"health"}"#, &scheduler, started);
    assert_eq!(keys(&health), ["ok", "op", "peers", "qos", "queued", "workers"]);
    scheduler.shutdown();
}

/// Hostile QoS fields on an otherwise-valid submit: each one must be a
/// structured per-frame error (never a silent downgrade to defaults,
/// never a dropped connection), and real traffic must still flow on
/// the same connection afterwards.
#[test]
fn hostile_qos_fields_get_structured_errors() {
    let (addr, handle) = Server::spawn("127.0.0.1:0", small_cfg()).expect("spawn server");
    let addr = addr.to_string();
    let base = Request::Submit {
        spec: small_spec(4),
        stream: false,
        qos: QoS::default(),
    }
    .to_json();
    let hostile: Vec<(&str, Json)> = vec![
        ("unknown class", {
            let mut j = base.clone();
            j.set("priority", "urgent");
            j
        }),
        ("numeric priority", {
            let mut j = base.clone();
            j.set("priority", 2u64);
            j
        }),
        ("negative deadline", {
            let mut j = base.clone();
            j.set("deadline_ms", -5i64);
            j
        }),
        ("fractional deadline", {
            let mut j = base.clone();
            j.set("deadline_ms", 1.5f64);
            j
        }),
        ("string deadline", {
            let mut j = base.clone();
            j.set("deadline_ms", "soon");
            j
        }),
        ("empty client id", {
            let mut j = base.clone();
            j.set("client", "");
            j
        }),
        ("oversized client id", {
            let mut j = base.clone();
            j.set("client", "c".repeat(65));
            j
        }),
        ("non-string client id", {
            let mut j = base.clone();
            j.set("client", 7u64);
            j
        }),
    ];
    let mut conn = RawConn::open(&addr);
    for (what, frame) in &hostile {
        let resp = conn
            .roundtrip(frame.to_string().as_bytes())
            .unwrap_or_else(|e| panic!("{what}: {e}"));
        assert_eq!(
            resp.get("ok").and_then(Json::as_bool),
            Some(false),
            "{what} must be rejected: {resp:?}"
        );
        let err = resp.get("error").and_then(Json::as_str).unwrap_or("");
        assert!(!err.is_empty(), "{what}: error message missing: {resp:?}");
    }
    // The connection survived all of it and a clean QoS submit works.
    let mut good = base.clone();
    good.set("priority", "interactive").set("deadline_ms", 30_000u64);
    let resp = conn
        .roundtrip(good.to_string().as_bytes())
        .expect("valid qos submit");
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true), "{resp:?}");
    // None of the hostile frames may have been admitted into a class.
    let mut c = Client::connect(&addr).expect("connect");
    let stats = c.stats().expect("stats");
    let admitted: u64 = ["background", "batch", "interactive"]
        .iter()
        .map(|class| {
            stats
                .get("scheduler")
                .and_then(|s| s.get("qos"))
                .and_then(|q| q.get(class))
                .and_then(|cl| cl.get("admitted"))
                .and_then(Json::as_u64)
                .unwrap_or_else(|| panic!("missing qos.{class}.admitted: {stats:?}"))
        })
        .sum();
    assert_eq!(admitted, 1, "only the one valid submit admits: {stats:?}");
    c.shutdown().expect("shutdown");
    let _ = handle.join();
}
