//! QoS end-to-end: weighted-fair scheduling properties, token-bucket
//! admission, deadline shedding and quota rejection over a real TCP
//! socket, and the per-class counter surfaces in `stats`.
//!
//! The property tests pin the scheduler-independent guarantees of the
//! [`WfqPicker`] (no backlogged class starves beyond its stride bound;
//! service shares track the configured weights) so a scheduler-side
//! regression in queue bookkeeping cannot hide behind wall-clock noise.

use barista::config::{ArchKind, SimConfig};
use barista::service::{
    Client, ClassWeights, JobSpec, Priority, QoS, QosConfig, Quota, Server, SchedulerConfig,
    TokenBuckets, WfqPicker,
};
use barista::util::prop::run_prop;
use barista::util::Json;
use barista::workload::Benchmark;

fn small_spec(seed: u64) -> JobSpec {
    let mut c = SimConfig::paper(ArchKind::Dense);
    c.window_cap = 16;
    c.batch = 1;
    c.seed = seed;
    JobSpec {
        benchmark: Benchmark::AlexNet,
        config: c,
    }
}

fn spawn_qos_server(
    qos: QosConfig,
) -> (std::net::SocketAddr, std::thread::JoinHandle<std::io::Result<()>>) {
    Server::spawn_full(
        "127.0.0.1:0",
        SchedulerConfig {
            workers: 2,
            shards: 2,
            queue_cap: 64,
            cache_bytes: 16 << 20,
            store: None,
        },
        qos,
        None,
    )
    .expect("bind ephemeral port")
}

/// Per-class QoS counter out of a `stats` response:
/// `scheduler.qos.<class>.<field>`.
fn qos_stat(stats: &Json, class: &str, field: &str) -> u64 {
    stats
        .get("scheduler")
        .and_then(|s| s.get("qos"))
        .and_then(|q| q.get(class))
        .and_then(|c| c.get(field))
        .and_then(Json::as_u64)
        .unwrap_or_else(|| panic!("missing scheduler.qos.{class}.{field} in {stats:?}"))
}

// ---- WFQ properties ----

/// No-starvation: while a class stays backlogged, consecutive services
/// of that class are at most `ceil(W / w_i) + CLASSES` picks apart
/// (stride scheduling's gap bound), and over a long all-backlogged run
/// each class's service count tracks `T * w_i / W` to within a few
/// picks.
#[test]
fn wfq_no_starvation_and_proportional_shares() {
    run_prop("wfq-no-starvation", 0xFA18, 200, |rng| {
        let w = [
            1 + rng.gen_range(8),
            1 + rng.gen_range(8),
            1 + rng.gen_range(8),
        ];
        let weights = ClassWeights::new(w[2], w[1], w[0]).expect("positive weights");
        let w_sum: u32 = w.iter().sum();
        let mut picker = WfqPicker::new(weights);
        let picks = (50 * w_sum) as usize;
        let mut count = [0usize; 3];
        let mut last = [0usize; 3];
        for t in 0..picks {
            let p = picker.pick([true, true, true]).expect("backlogged");
            let i = p.index();
            // Gap bound per class: ceil(W/w_j) + number of classes.
            for (j, &c) in w.iter().enumerate() {
                let gap = t - last[j];
                let bound = (w_sum as usize + c as usize - 1) / c as usize + 3;
                if gap > bound {
                    return Err(format!(
                        "class {j} starved for {gap} picks (weights {w:?}, bound {bound})"
                    ));
                }
            }
            count[i] += 1;
            last[i] = t;
        }
        for (i, &c) in count.iter().enumerate() {
            let expect = picks as f64 * w[i] as f64 / w_sum as f64;
            if (c as f64 - expect).abs() > 3.0 {
                return Err(format!(
                    "class {i} served {c} times, expected ~{expect:.1} (weights {w:?})"
                ));
            }
        }
        Ok(())
    });
}

/// The picker only ever serves classes with queued work, and returns
/// `None` exactly when nothing is queued — under arbitrary backlog
/// masks and idle periods (`note_nonempty` clamping included).
#[test]
fn wfq_pick_respects_backlog_mask() {
    run_prop("wfq-mask", 0xFA19, 200, |rng| {
        let weights = ClassWeights::new(
            1 + rng.gen_range(8),
            1 + rng.gen_range(8),
            1 + rng.gen_range(8),
        )
        .expect("positive weights");
        let mut picker = WfqPicker::new(weights);
        for _ in 0..100 {
            let mask = [rng.gen_bool(0.6), rng.gen_bool(0.6), rng.gen_bool(0.6)];
            if rng.gen_bool(0.2) {
                picker.note_nonempty(Priority::from_index(rng.gen_range(3) as usize));
            }
            match picker.pick(mask) {
                None => {
                    if mask != [false; 3] {
                        return Err(format!("None despite backlog {mask:?}"));
                    }
                }
                Some(p) => {
                    if !mask[p.index()] {
                        return Err(format!("picked empty class {p:?} from {mask:?}"));
                    }
                }
            }
        }
        Ok(())
    });
}

// ---- token buckets ----

#[test]
fn token_buckets_enforce_rate_per_client_and_bound_tracking() {
    let b = TokenBuckets::new(Quota::per_second(10.0).expect("rate")); // burst 20
    // The burst is forgiven, then admission fails with a real hint.
    for i in 0..20 {
        assert!(b.admit_at(Some("alice"), 0).is_ok(), "burst admit {i}");
    }
    let retry = b.admit_at(Some("alice"), 0).expect_err("bucket dry");
    assert!(retry >= 1, "retry hint must be at least 1 ms, got {retry}");
    // A different client has its own bucket; anonymous has the shared one.
    assert!(b.admit_at(Some("bob"), 0).is_ok());
    assert!(b.admit_at(None, 0).is_ok());
    // Waiting the hinted time refills exactly enough for one admit.
    assert!(b.admit_at(Some("alice"), retry).is_ok());
    assert!(b.admit_at(Some("alice"), retry).is_err());
    // Client-id churn cannot grow the map without bound: past the cap,
    // new ids share the overflow bucket.
    for i in 0..5000 {
        let _ = b.admit_at(Some(&format!("churn{i}")), 1_000_000);
    }
    assert!(
        b.tracked() <= 4096,
        "tracked clients must stay bounded, got {}",
        b.tracked()
    );
}

// ---- over the wire ----

#[test]
fn qos_envelope_roundtrips_and_default_traffic_unchanged() {
    let (addr, server) = spawn_qos_server(QosConfig::default());
    let mut client = Client::connect(&addr.to_string()).expect("connect");

    // Plain submit (no QoS): unchanged behavior, counted as batch class.
    let plain = client.submit(&small_spec(1)).expect("plain submit");
    assert_eq!(plain.get("ok").and_then(Json::as_bool), Some(true), "{plain:?}");

    // QoS submit: same result bytes, counted as interactive.
    let qos = QoS {
        priority: Priority::Interactive,
        client: Some("it".into()),
        deadline_ms: Some(30_000),
    };
    let fancy = client.submit_qos(&small_spec(1), &qos).expect("qos submit");
    assert_eq!(fancy.get("ok").and_then(Json::as_bool), Some(true), "{fancy:?}");
    assert_eq!(
        plain.get("result").map(Json::to_string),
        fancy.get("result").map(Json::to_string),
        "QoS envelope must not change the result payload"
    );

    let stats = client.stats().expect("stats");
    assert_eq!(qos_stat(&stats, "batch", "admitted"), 1);
    assert_eq!(qos_stat(&stats, "interactive", "admitted"), 1);
    assert_eq!(qos_stat(&stats, "interactive", "shed_deadline"), 0);

    client.shutdown().expect("shutdown");
    server.join().expect("server thread").expect("server io");
}

#[test]
fn expired_deadline_is_shed_over_the_wire_with_exact_counters() {
    let (addr, server) = spawn_qos_server(QosConfig::default());
    let mut client = Client::connect(&addr.to_string()).expect("connect");

    // deadline_ms = 0 expires at enqueue time: the job must be shed at
    // pop, never simulated.
    let qos = QoS {
        priority: Priority::Background,
        client: None,
        deadline_ms: Some(0),
    };
    let resp = client.submit_qos(&small_spec(2), &qos).expect("submit");
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false), "{resp:?}");
    assert_eq!(
        resp.get("error").and_then(Json::as_str),
        Some("deadline_exceeded"),
        "{resp:?}"
    );
    assert_eq!(resp.get("shed").and_then(Json::as_bool), Some(true), "{resp:?}");

    let stats = client.stats().expect("stats");
    assert_eq!(qos_stat(&stats, "background", "shed_deadline"), 1);
    assert_eq!(qos_stat(&stats, "background", "admitted"), 1);
    let executed = stats
        .get("scheduler")
        .and_then(|s| s.get("executed"))
        .and_then(Json::as_u64);
    assert_eq!(executed, Some(0), "shed job must not simulate: {stats:?}");

    // The same job without a deadline computes normally afterwards.
    let ok = client.submit(&small_spec(2)).expect("resubmit");
    assert_eq!(ok.get("ok").and_then(Json::as_bool), Some(true), "{ok:?}");

    client.shutdown().expect("shutdown");
    server.join().expect("server thread").expect("server io");
}

#[test]
fn quota_rejects_over_the_wire_with_retry_hint() {
    let (addr, server) = spawn_qos_server(QosConfig {
        weights: ClassWeights::default(),
        // Effectively non-refilling within the test: burst of 2 only.
        quota: Some(Quota {
            rate_per_s: 0.001,
            burst: 2.0,
        }),
    });
    let mut client = Client::connect(&addr.to_string()).expect("connect");

    let qos = |name: &str| QoS {
        priority: Priority::Batch,
        client: Some(name.into()),
        deadline_ms: None,
    };
    for i in 0..2 {
        let r = client.submit_qos(&small_spec(3), &qos("alice")).expect("submit");
        assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true), "admit {i}: {r:?}");
    }
    let rejected = client.submit_qos(&small_spec(3), &qos("alice")).expect("submit");
    assert_eq!(rejected.get("ok").and_then(Json::as_bool), Some(false), "{rejected:?}");
    assert_eq!(
        rejected.get("error").and_then(Json::as_str),
        Some("quota_exceeded"),
        "{rejected:?}"
    );
    assert!(
        rejected
            .get("retry_after_ms")
            .and_then(Json::as_u64)
            .is_some_and(|ms| ms >= 1),
        "{rejected:?}"
    );

    // A different client still has its own burst.
    let bob = client.submit_qos(&small_spec(3), &qos("bob")).expect("submit");
    assert_eq!(bob.get("ok").and_then(Json::as_bool), Some(true), "{bob:?}");

    let stats = client.stats().expect("stats");
    assert_eq!(qos_stat(&stats, "batch", "quota_rejected"), 1);
    assert_eq!(qos_stat(&stats, "batch", "admitted"), 3);

    client.shutdown().expect("shutdown");
    server.join().expect("server thread").expect("server io");
}
