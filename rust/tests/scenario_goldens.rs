//! Pinned scenario goldens: one cycle count per (sparsity model,
//! architecture) pair, extending the self-sealing scheme of
//! `perf_equivalence.rs` (see `tests/golden/README.md`).
//!
//! Equivalence and invariant tests re-derive both sides of every
//! comparison, so only pinned constants catch *silent* semantic drift —
//! in the scenario engine itself (a mask-generation tweak changes every
//! non-default model) or in any architecture's timing model. On the
//! first run in a fresh environment each missing file seals itself with
//! the measured value; once committed, a change must be deliberate:
//! bump `SIM_VERSION` in `src/lib.rs` and refresh the files together.

use barista::config::{ArchKind, SimConfig};
use barista::coordinator::{run_one, RunRequest};
use barista::workload::{Benchmark, SparsityModel};

#[test]
fn pinned_golden_cycles_per_model_and_architecture() {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/golden");
    std::fs::create_dir_all(dir).expect("create golden dir");
    let mut sealed = 0usize;
    let mut checked = 0usize;
    for model in SparsityModel::ALL {
        for arch in ArchKind::ALL {
            let mut cfg = SimConfig::paper(arch);
            cfg.window_cap = 24;
            cfg.batch = 1;
            cfg.sparsity = model;
            let got = run_one(&RunRequest {
                benchmark: Benchmark::AlexNet,
                config: cfg,
            })
            .network
            .cycles;
            assert!(
                got.is_finite() && got > 0.0,
                "{model} on {arch}: insane cycles {got}"
            );
            let path = format!(
                "{dir}/scenario_{}_{}_cycles.txt",
                model.spec().replace(':', "-"),
                arch.name()
            );
            match std::fs::read_to_string(&path) {
                Ok(s) => {
                    let want: f64 = s.trim().parse().unwrap_or_else(|e| {
                        panic!("golden file {path} must hold one f64: {e}")
                    });
                    assert_eq!(
                        got.to_bits(),
                        want.to_bits(),
                        "pinned cycles for {model} on {arch} drifted: got {got}, \
                         golden {want}. If intentional, bump SIM_VERSION in \
                         src/lib.rs and refresh {path}."
                    );
                    checked += 1;
                }
                Err(_) => {
                    std::fs::write(&path, format!("{got}\n")).expect("seal golden file");
                    sealed += 1;
                }
            }
        }
    }
    println!(
        "scenario goldens: {checked} checked, {sealed} sealed \
         ({} models × {} architectures)",
        SparsityModel::ALL.len(),
        ArchKind::ALL.len()
    );
}
