//! Crash-recovery and warm-restart integration tests for the persistent
//! result store (DESIGN.md §Store).
//!
//! The headline guarantees under test:
//!
//! 1. **Corrupt-tail tolerance** (property): truncating a valid journal
//!    at *any* byte recovers every fully-written record and drops only
//!    the torn tail — never a middle record, never the whole file.
//! 2. **Warm restart**: a scheduler/server killed and restarted on the
//!    same `--cache-dir` serves previously submitted configs from the
//!    cold tier with zero re-simulation (the scheduler records a store
//!    hit, not a sim run), byte-identical to the original results.
//!
//! Like `invariants.rs`, the property test scales with `PROP_CASES` and
//! reseeds from `PROP_SEED` (decimal) for the nightly deep run.

use std::sync::Arc;

use barista::config::{ArchKind, SimConfig};
use barista::coordinator::{run_one, sweep_requests, RunRequest};
use barista::service::store::encode_record;
use barista::service::{
    cache::canonical_job_string, job_key, Client, JobKey, JobSpec, Scheduler, SchedulerConfig,
    Server, Source, Store,
};
use barista::util::prop::run_prop;
use barista::util::{scratch_dir, Json};
use barista::workload::Benchmark;

fn env_u64(name: &str, default: u64) -> u64 {
    match std::env::var(name) {
        Err(_) => default,
        Ok(v) => v
            .parse()
            .unwrap_or_else(|e| panic!("bad {name}='{v}': {e}")),
    }
}

fn prop_seed() -> u64 {
    env_u64("PROP_SEED", 0xBA7157A)
}

fn cases(base: u64) -> u64 {
    base * env_u64("PROP_CASES", 1).max(1)
}

fn small_cfg(arch: ArchKind, seed: u64) -> SimConfig {
    let mut c = SimConfig::paper(arch);
    c.window_cap = 16;
    c.batch = 1;
    c.seed = seed;
    c
}

fn small_req(arch: ArchKind, seed: u64) -> RunRequest {
    RunRequest {
        benchmark: Benchmark::AlexNet,
        config: small_cfg(arch, seed),
    }
}

fn store_sched(store: Arc<Store>) -> Scheduler {
    Scheduler::new(SchedulerConfig {
        workers: 2,
        shards: 2,
        queue_cap: 64,
        cache_bytes: 16 << 20,
        store: Some(store),
    })
}

/// A synthetic but version-current record payload of tunable size.
fn raw_payload(i: u64, pad: usize) -> String {
    format!(
        r#"{{"canon":"sim-v{}|prop|{}","pad":"{}"}}"#,
        barista::SIM_VERSION,
        i,
        "p".repeat(pad)
    )
}

/// Property: any byte-truncation of a valid journal recovers exactly
/// the records that were fully written before the cut and drops only
/// the torn tail.
#[test]
fn prop_journal_truncation_recovers_every_complete_record() {
    run_prop(
        "journal truncation recovers prefix",
        prop_seed(),
        cases(16),
        |rng| {
            // Build a journal of 2..=9 records with varied payload sizes.
            let nrecords = 2 + rng.gen_range(8) as usize;
            let dir = scratch_dir("prop-journal");
            let mut records: Vec<(JobKey, String)> = Vec::new();
            // Record end offsets (journal byte boundaries), in order.
            let mut boundaries: Vec<u64> = Vec::new();
            {
                let store = Store::open_with(&dir, false).map_err(|e| e.to_string())?;
                for i in 0..nrecords {
                    let key = JobKey(i as u64 + 1, rng.next_u64());
                    let payload = raw_payload(i as u64, rng.gen_range(200) as usize);
                    store.put(key, &payload).map_err(|e| e.to_string())?;
                    boundaries.push(store.stats().journal_bytes);
                    records.push((key, payload));
                }
            }
            let journal = dir.join("journal.bjl");
            let bytes = std::fs::read(&journal).map_err(|e| e.to_string())?;
            let header_len = boundaries[0]
                - (records[0].1.len() as u64 + 28 /* record frame */);

            // Truncate at an arbitrary point past the header (a cut
            // inside the header itself is a different failure class —
            // open() rejects the file as not-a-journal).
            let span = (bytes.len() as u64 - header_len + 1) as u32;
            let cut = header_len + rng.gen_range(span) as u64;
            let dir2 = scratch_dir("prop-journal-cut");
            std::fs::write(dir2.join("journal.bjl"), &bytes[..cut as usize])
                .map_err(|e| e.to_string())?;

            let expect_complete = boundaries.iter().filter(|&&b| b <= cut).count();
            let store = Store::open_with(&dir2, false).map_err(|e| e.to_string())?;
            let st = store.stats();
            if st.recovered_records != expect_complete {
                return Err(format!(
                    "cut at {cut}: recovered {} records, expected {expect_complete} \
                     (boundaries {boundaries:?})",
                    st.recovered_records
                ));
            }
            // Record ends, the bare header, and the full file are all
            // clean boundaries — no torn tail to drop there.
            let at_boundary =
                cut == bytes.len() as u64 || cut == header_len || boundaries.contains(&cut);
            if st.dropped_tail == at_boundary {
                return Err(format!(
                    "cut at {cut}: dropped_tail={} but at_boundary={at_boundary}",
                    st.dropped_tail
                ));
            }
            // Every complete record reads back bit-identically; every
            // torn one is absent.
            for (i, (key, payload)) in records.iter().enumerate() {
                let got = store.get(key);
                if i < expect_complete {
                    if got.as_deref() != Some(payload.as_str()) {
                        return Err(format!("cut at {cut}: record {i} corrupted or missing"));
                    }
                } else if got.is_some() {
                    return Err(format!("cut at {cut}: torn record {i} resurrected"));
                }
            }
            // The repaired journal accepts appends and survives reopen.
            let extra = raw_payload(999, 10);
            store
                .put(JobKey(0xFFFF, 0xFFFF), &extra)
                .map_err(|e| e.to_string())?;
            drop(store);
            let store = Store::open_with(&dir2, false).map_err(|e| e.to_string())?;
            if store.get(&JobKey(0xFFFF, 0xFFFF)).as_deref() != Some(extra.as_str()) {
                return Err(format!("cut at {cut}: post-repair append lost"));
            }
            let _ = std::fs::remove_dir_all(&dir);
            let _ = std::fs::remove_dir_all(&dir2);
            Ok(())
        },
    );
}

/// The acceptance-criteria test: kill the scheduler, restart on the
/// same cache dir, and prove the previously submitted config is served
/// from the cold tier with zero re-simulation.
#[test]
fn scheduler_warm_restart_serves_from_the_cold_tier() {
    let dir = scratch_dir("warm-restart-sched");
    let req = small_req(ArchKind::Barista, 41);

    // First lifetime: simulate and journal.
    let first_json;
    {
        let sched = store_sched(Arc::new(Store::open(&dir).unwrap()));
        let out = sched.execute(&req).unwrap();
        assert_eq!(out.source, Source::Executed);
        first_json = out.entry.network_json.clone();
        let st = sched.stats();
        assert_eq!(st.executed, 1);
        assert_eq!(st.store.unwrap().records, 1);
        sched.shutdown();
    } // drop = kill

    // Second lifetime: fresh process state, same directory.
    let sched = store_sched(Arc::new(Store::open(&dir).unwrap()));
    let out = sched.execute(&req).unwrap();
    assert_eq!(
        out.source,
        Source::StoreHit,
        "restarted scheduler must record a store hit, not a sim run"
    );
    assert_eq!(out.entry.network_json, first_json, "byte-identical replay");
    let st = sched.stats();
    assert_eq!(st.executed, 0, "zero re-simulation after restart");
    assert_eq!(st.store_hits, 1);

    // Full structured fidelity (the report path consumes these fields,
    // not the JSON): energy/traffic/breakdown all bit-identical.
    let direct = run_one(&req);
    let got = &out.entry.result;
    assert_eq!(got.network.cycles, direct.network.cycles);
    assert_eq!(got.network.breakdown, direct.network.breakdown);
    assert_eq!(got.network.traffic, direct.network.traffic);
    assert_eq!(got.network.energy, direct.network.energy);

    // Third submission in the same lifetime: admitted to the hot tier
    // by the cold hit, so it is now a plain cache hit.
    assert_eq!(sched.execute(&req).unwrap().source, Source::CacheHit);
    drop(sched);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Dedup consults the cold tier before scheduling: a warm store means a
/// whole batch of repeats produces zero executions.
#[test]
fn batch_on_a_warm_store_schedules_no_work() {
    let dir = scratch_dir("warm-batch");
    let reqs = sweep_requests(
        &[Benchmark::AlexNet],
        &[ArchKind::Dense, ArchKind::Barista, ArchKind::Ideal],
        &small_cfg(ArchKind::Barista, 43),
    );
    {
        let sched = store_sched(Arc::new(Store::open(&dir).unwrap()));
        sched.run_all(&reqs).unwrap();
        assert_eq!(sched.stats().executed, 3);
    }
    let sched = store_sched(Arc::new(Store::open(&dir).unwrap()));
    // Repeats of the same job inside one batch: first is a store hit,
    // the rest hot-cache hits (admission), never an execution.
    let mut batch = reqs.clone();
    batch.extend(reqs.iter().cloned());
    let out = sched.run_all(&batch).unwrap();
    let st = sched.stats();
    assert_eq!(st.executed, 0, "warm store schedules zero work: {st:?}");
    assert_eq!(st.store_hits, 3, "{st:?}");
    assert_eq!(st.cache_hits, 3, "{st:?}");
    for (o, r) in out.iter().zip(&batch) {
        assert_eq!(o.entry.result.arch, r.config.arch);
        assert!(
            matches!(o.source, Source::StoreHit | Source::CacheHit),
            "{:?}",
            o.source
        );
    }
    drop(sched);
    let _ = std::fs::remove_dir_all(&dir);
}

/// End-to-end over TCP: kill and restart the *server* with the same
/// --cache-dir; the wire response after restart reports source "store".
#[test]
fn server_kill_and_restart_replays_from_disk() {
    let dir = scratch_dir("warm-restart-server");
    let spec = JobSpec {
        benchmark: Benchmark::AlexNet,
        config: small_cfg(ArchKind::Barista, 47),
    };
    let cfg = |store: Arc<Store>| SchedulerConfig {
        workers: 2,
        shards: 2,
        queue_cap: 64,
        cache_bytes: 16 << 20,
        store: Some(store),
    };

    // Lifetime 1: simulate, respond, shut down.
    let first_result;
    {
        let (addr, server) =
            Server::spawn("127.0.0.1:0", cfg(Arc::new(Store::open(&dir).unwrap()))).unwrap();
        let mut client = Client::connect(&addr.to_string()).unwrap();
        let resp = client.submit(&spec).unwrap();
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true), "{resp:?}");
        assert_eq!(
            resp.get("source").and_then(Json::as_str),
            Some("executed")
        );
        first_result = resp.get("result").unwrap().to_string();
        client.shutdown().unwrap();
        server.join().unwrap().unwrap();
    }

    // Lifetime 2: same directory, fresh server; zero re-simulation.
    let (addr, server) =
        Server::spawn("127.0.0.1:0", cfg(Arc::new(Store::open(&dir).unwrap()))).unwrap();
    let mut client = Client::connect(&addr.to_string()).unwrap();
    let resp = client.submit(&spec).unwrap();
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true), "{resp:?}");
    assert_eq!(
        resp.get("source").and_then(Json::as_str),
        Some("store"),
        "restarted server must serve from the cold tier: {resp:?}"
    );
    assert_eq!(
        resp.get("result").unwrap().to_string(),
        first_result,
        "byte-identical across the restart"
    );
    let stats = client.stats().unwrap();
    let sched = stats.get("scheduler").unwrap();
    assert_eq!(sched.get("executed").and_then(Json::as_u64), Some(0));
    assert_eq!(sched.get("store_hits").and_then(Json::as_u64), Some(1));
    assert!(
        sched.get("store").is_some(),
        "stats expose cold-tier counters: {stats:?}"
    );
    client.shutdown().unwrap();
    server.join().unwrap().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

/// The `report --figure all` warm path: a full (mini) sweep against a
/// warm store re-simulates nothing and reproduces every figure input
/// bit-identically.
#[test]
fn warm_sweep_reproduces_results_with_zero_simulation() {
    let dir = scratch_dir("warm-sweep");
    let reqs = sweep_requests(
        &[Benchmark::AlexNet],
        &[ArchKind::Dense, ArchKind::SparTen, ArchKind::Barista, ArchKind::Ideal],
        &small_cfg(ArchKind::Barista, 51),
    );
    let cold_results;
    {
        let sched = store_sched(Arc::new(Store::open(&dir).unwrap()));
        cold_results = sched.run_results(&reqs).unwrap();
    }
    let sched = store_sched(Arc::new(Store::open(&dir).unwrap()));
    let warm_results = sched.run_results(&reqs).unwrap();
    assert_eq!(sched.stats().executed, 0);
    for (a, b) in cold_results.iter().zip(&warm_results) {
        assert_eq!(
            a.network.to_json().to_string(),
            b.network.to_json().to_string()
        );
        assert_eq!(a.network.energy, b.network.energy);
    }
    drop(sched);
    let _ = std::fs::remove_dir_all(&dir);
}

/// A journal record carries everything the tiered cache needs: priming
/// a store *by hand* (encode_record) and reading through a fresh
/// scheduler reproduces run_one exactly.
#[test]
fn hand_primed_store_serves_decodable_records() {
    let dir = scratch_dir("hand-primed");
    let req = small_req(ArchKind::SparTen, 53);
    let result = run_one(&req);
    {
        let store = Store::open(&dir).unwrap();
        store
            .put(
                job_key(&req),
                &encode_record(&result, &canonical_job_string(&req)),
            )
            .unwrap();
    }
    let sched = store_sched(Arc::new(Store::open(&dir).unwrap()));
    let out = sched.execute(&req).unwrap();
    assert_eq!(out.source, Source::StoreHit);
    assert_eq!(
        out.entry.network_json,
        result.network.to_json().to_string()
    );
    drop(sched);
    let _ = std::fs::remove_dir_all(&dir);
}
