//! Trace-fitting goldens and the fit round-trip property (ISSUE 10).
//!
//! Two self-sealing golden families over the shipped presets under
//! `rust/traces/` (same scheme as `scenario_goldens.rs` — first run in
//! a fresh environment seals, committed files then pin):
//!
//! 1. the full fit report (`LoadedTrace::describe()`): per-layer fitted
//!    model parameters, residuals, and the registered cache identity;
//! 2. scenario cycles per (preset, arch): the trace's fitted network
//!    under its fitted model through the real simulator.
//!
//! Plus the seeded round-trip property (`PROP_SEED`/`PROP_CASES`
//! convention from `tests/invariants.rs`): synthesize a trace from each
//! `SparsityModel`, fit it, and assert the fitted parameters recover
//! the generator within tolerance — and the cache-key law: two traces
//! sharing a display name but differing in content must never share a
//! service cache entry.

use barista::config::{ArchKind, SimConfig};
use barista::coordinator::{run_one, RunRequest};
use barista::service::cache::canonical_job_string;
use barista::service::{job_key, JobSpec};
use barista::util::prop::run_prop;
use barista::workload::traces::{fit_trace, parse_trace};
use barista::workload::{load_trace_file, load_trace_json, synthesize_trace_json, SparsityModel};

/// Read a tuning env var; a set-but-unparseable value is a hard error,
/// never a silent fall-back.
fn env_u64(name: &str, default: u64) -> u64 {
    match std::env::var(name) {
        Err(_) => default,
        Ok(s) => s
            .parse()
            .unwrap_or_else(|e| panic!("{name}='{s}' must be a decimal integer: {e}")),
    }
}

fn prop_seed() -> u64 {
    env_u64("PROP_SEED", 0xBA7157A)
}

fn cases(base: u64) -> u64 {
    base * env_u64("PROP_CASES", 1).max(1)
}

/// The shipped presets: (file stem, path).
const PRESETS: [(&str, &str); 2] = [
    (
        "spiking_resnet",
        concat!(env!("CARGO_MANIFEST_DIR"), "/traces/spiking_resnet.json"),
    ),
    (
        "pruned_cnn",
        concat!(env!("CARGO_MANIFEST_DIR"), "/traces/pruned_cnn.json"),
    ),
];

/// Mirror of main.rs's scenario arch set (Dense baseline, strongest
/// prior two-sided design, BARISTA, Ideal bound).
const SCENARIO_ARCHS: [ArchKind; 4] = [
    ArchKind::Dense,
    ArchKind::SparTen,
    ArchKind::Barista,
    ArchKind::Ideal,
];

fn golden_dir() -> &'static str {
    concat!(env!("CARGO_MANIFEST_DIR"), "/tests/golden")
}

/// Seal-or-compare a golden holding arbitrary text.
fn check_text_golden(path: &str, got: &str, what: &str) -> bool {
    match std::fs::read_to_string(path) {
        Ok(want) => {
            assert_eq!(
                got, want,
                "{what} drifted from golden {path}. If intentional, bump \
                 SIM_VERSION in src/lib.rs and refresh the file."
            );
            true
        }
        Err(_) => {
            std::fs::write(path, got).expect("seal golden file");
            false
        }
    }
}

#[test]
fn preset_fit_reports_are_pinned() {
    std::fs::create_dir_all(golden_dir()).expect("create golden dir");
    let mut sealed = 0usize;
    for (stem, path) in PRESETS {
        let t = load_trace_file(path).expect("load preset");
        let got = t.describe();
        let gpath = format!("{}/trace_fit_{stem}.txt", golden_dir());
        if !check_text_golden(&gpath, &got, &format!("fit report for {stem}")) {
            sealed += 1;
        }
    }
    println!("trace fit goldens: {} presets, {sealed} sealed", PRESETS.len());
}

#[test]
fn preset_scenario_cycles_are_pinned() {
    std::fs::create_dir_all(golden_dir()).expect("create golden dir");
    let mut sealed = 0usize;
    let mut checked = 0usize;
    for (stem, path) in PRESETS {
        let t = load_trace_file(path).expect("load preset");
        for arch in SCENARIO_ARCHS {
            let mut cfg = SimConfig::paper(arch);
            cfg.window_cap = 24;
            cfg.batch = 1;
            cfg.sparsity = t.fit.model;
            let got = run_one(&RunRequest {
                benchmark: t.benchmark,
                config: cfg,
            })
            .network
            .cycles;
            assert!(
                got.is_finite() && got > 0.0,
                "{stem} on {arch}: insane cycles {got}"
            );
            let gpath = format!("{}/trace_scn_{stem}_{}_cycles.txt", golden_dir(), arch.name());
            match std::fs::read_to_string(&gpath) {
                Ok(s) => {
                    let want: f64 = s.trim().parse().unwrap_or_else(|e| {
                        panic!("golden file {gpath} must hold one f64: {e}")
                    });
                    assert_eq!(
                        got.to_bits(),
                        want.to_bits(),
                        "pinned cycles for {stem} on {arch} drifted: got {got}, \
                         golden {want}. If intentional, bump SIM_VERSION in \
                         src/lib.rs and refresh {gpath}."
                    );
                    checked += 1;
                }
                Err(_) => {
                    std::fs::write(&gpath, format!("{got}\n")).expect("seal golden file");
                    sealed += 1;
                }
            }
        }
    }
    println!("trace scenario goldens: {checked} checked, {sealed} sealed");
}

/// The anti-aliasing law at the service layer: two traces with the same
/// display name but different content get distinct memo/cache keys for
/// otherwise identical jobs; the identical document keys identically.
#[test]
fn same_name_different_content_never_shares_a_cache_key() {
    let a = load_trace_json(&synthesize_trace_json(
        "alias-check",
        SparsityModel::Bernoulli,
        0.40,
        0.40,
        1,
        96,
        768,
        101,
    ))
    .expect("load a");
    let b = load_trace_json(&synthesize_trace_json(
        "alias-check",
        SparsityModel::Bernoulli,
        0.40,
        0.40,
        1,
        96,
        768,
        202,
    ))
    .expect("load b");
    assert_eq!(a.name, b.name, "the display names collide by design");
    let cfg = SimConfig::paper(ArchKind::Barista);
    let ra = RunRequest {
        benchmark: a.benchmark,
        config: cfg.clone(),
    };
    let rb = RunRequest {
        benchmark: b.benchmark,
        config: cfg.clone(),
    };
    assert_ne!(
        canonical_job_string(&ra),
        canonical_job_string(&rb),
        "distinct traces must never share a canonical job string"
    );
    assert_ne!(
        job_key(&ra),
        job_key(&rb),
        "distinct traces must never share a cache key"
    );
    // And the dedup direction: the identical document keys identically.
    let a2 = load_trace_json(&synthesize_trace_json(
        "alias-check",
        SparsityModel::Bernoulli,
        0.40,
        0.40,
        1,
        96,
        768,
        101,
    ))
    .expect("reload a");
    let ra2 = RunRequest {
        benchmark: a2.benchmark,
        config: cfg,
    };
    assert_eq!(canonical_job_string(&ra), canonical_job_string(&ra2));
    assert_eq!(job_key(&ra), job_key(&ra2));
}

/// A traced job survives the wire protocol round trip: the embedded
/// `network_spec` re-registers on the receiving side to the same cache
/// identity and the same simulation config.
#[test]
fn traced_jobs_round_trip_the_wire_protocol() {
    for (_, path) in PRESETS {
        let t = load_trace_file(path).expect("load preset");
        let mut cfg = SimConfig::paper(ArchKind::Barista);
        cfg.window_cap = 48;
        cfg.sparsity = t.fit.model;
        let spec = JobSpec {
            benchmark: t.benchmark,
            config: cfg,
        };
        let wire = spec.to_json();
        assert!(
            wire.get("network_spec").is_some(),
            "traced job must embed its network_spec on the wire"
        );
        let back = JobSpec::from_json(&wire).expect("decode traced job");
        assert_eq!(
            back.benchmark.cache_token(),
            spec.benchmark.cache_token(),
            "wire round trip must preserve the trace's cache identity"
        );
        assert_eq!(
            back.config.canonical_json().to_string(),
            spec.config.canonical_json().to_string()
        );
    }
}

/// Round-trip property: synthesize a trace from a known generator, fit
/// it, and the fitted parameters must recover the generator within
/// tolerance. Tolerances are grid-aware (the candidate grids are
/// log-spaced, so "within a factor of 4" means the fit landed on the
/// true grid point or one of its neighbours).
#[test]
fn prop_fit_recovers_generator() {
    run_prop("fit_recovers_generator", prop_seed(), cases(6), |rng| {
        let d = 0.25 + 0.2 * rng.next_f64();
        let name = format!("rt-{}", rng.next_u64());
        let seed = rng.next_u64();
        match rng.gen_range(5) {
            0 => {
                let gen = [8u32, 32, 128][rng.gen_range(3) as usize];
                let j = synthesize_trace_json(
                    &name,
                    SparsityModel::Clustered { run: gen },
                    0.35,
                    d,
                    1,
                    96,
                    768,
                    seed,
                );
                let fit = fit_trace(&parse_trace(&j)?);
                let side = fit.layers[0].windows.model;
                let SparsityModel::Clustered { run } = side else {
                    return Err(format!(
                        "clustered:{gen} at d={d:.3} fitted as {side} on the window side"
                    ));
                };
                if run * 4 < gen || run > gen * 4 {
                    return Err(format!(
                        "clustered:{gen} at d={d:.3} fitted run {run} (outside 4x)"
                    ));
                }
                if fit.model.family() != "clustered" {
                    return Err(format!(
                        "clustered:{gen}: network model {} is not clustered",
                        fit.model
                    ));
                }
            }
            1 => {
                let gen = [10u32, 25, 50, 75][rng.gen_range(4) as usize];
                let j = synthesize_trace_json(
                    &name,
                    SparsityModel::ChannelSkew { hot_pct: gen },
                    d,
                    0.35,
                    1,
                    96,
                    768,
                    seed,
                );
                let fit = fit_trace(&parse_trace(&j)?);
                let side = fit.layers[0].filters.model;
                let SparsityModel::ChannelSkew { hot_pct } = side else {
                    return Err(format!(
                        "channel-skew:{gen} at d={d:.3} fitted as {side} on the filter side"
                    ));
                };
                if hot_pct.abs_diff(gen) > 35 {
                    return Err(format!(
                        "channel-skew:{gen} at d={d:.3} fitted hot_pct {hot_pct}"
                    ));
                }
            }
            2 => {
                let gen = [4u32, 8, 16, 32, 64][rng.gen_range(5) as usize];
                let j = synthesize_trace_json(
                    &name,
                    SparsityModel::BankBalanced { bank: gen },
                    d,
                    0.35,
                    1,
                    96,
                    768,
                    seed,
                );
                let fit = fit_trace(&parse_trace(&j)?);
                let side = fit.layers[0].filters.model;
                let SparsityModel::BankBalanced { bank } = side else {
                    return Err(format!(
                        "bank-balanced:{gen} at d={d:.3} fitted as {side} on the filter side"
                    ));
                };
                if bank * 4 < gen || bank > gen * 4 {
                    return Err(format!(
                        "bank-balanced:{gen} at d={d:.3} fitted bank {bank} (outside 4x)"
                    ));
                }
            }
            3 => {
                // LayerDecay's whole effect is the depth profile, and
                // the derived spec pins the per-layer means exactly —
                // recovery means the means decay monotonically.
                let j = synthesize_trace_json(
                    &name,
                    SparsityModel::LayerDecay { decay_pct: 40 },
                    0.35,
                    0.45,
                    4,
                    96,
                    768,
                    seed,
                );
                let fit = fit_trace(&parse_trace(&j)?);
                for w in fit.layers.windows(2) {
                    if w[1].map_density >= w[0].map_density {
                        return Err(format!(
                            "layer-decay:40 means not decreasing: {} -> {}",
                            w[0].map_density, w[1].map_density
                        ));
                    }
                }
            }
            _ => {
                let j = synthesize_trace_json(
                    &name,
                    SparsityModel::Bernoulli,
                    0.35,
                    d,
                    1,
                    96,
                    768,
                    seed,
                );
                let fit = fit_trace(&parse_trace(&j)?);
                if fit.model.family() != "bernoulli" {
                    return Err(format!(
                        "bernoulli at d={d:.3} fitted as {} (residual {:.4})",
                        fit.model, fit.residual
                    ));
                }
            }
        }
        Ok(())
    });
}
